//! Transfer task descriptions and completion reports.

use super::endpoint::EndpointId;

/// One file inside a transfer request.
#[derive(Debug, Clone)]
pub struct FileSpec {
    pub name: String,
    pub bytes: u64,
}

impl FileSpec {
    pub fn new(name: impl Into<String>, bytes: u64) -> FileSpec {
        FileSpec {
            name: name.into(),
            bytes,
        }
    }
}

/// A multi-file transfer between two endpoints.
#[derive(Debug, Clone)]
pub struct TransferRequest {
    pub label: String,
    pub src: EndpointId,
    pub dst: EndpointId,
    pub files: Vec<FileSpec>,
    /// number of files moved concurrently (Globus `--concurrency`);
    /// `None` lets the service auto-tune (paper §3: "automatically tuning
    /// parameters to maximize bandwidth usage").
    pub concurrency: Option<usize>,
    /// verify checksums at the destination after each file
    pub verify_checksum: bool,
}

impl TransferRequest {
    pub fn total_bytes(&self) -> u64 {
        self.files.iter().map(|f| f.bytes).sum()
    }

    /// Convenience: one logical dataset split into `n` equal files.
    pub fn split_even(
        label: impl Into<String>,
        src: EndpointId,
        dst: EndpointId,
        total_bytes: u64,
        n_files: usize,
    ) -> TransferRequest {
        assert!(n_files > 0);
        let per = total_bytes / n_files as u64;
        let mut files: Vec<FileSpec> = (0..n_files)
            .map(|i| FileSpec::new(format!("part-{i:05}"), per))
            .collect();
        // remainder onto the last file so totals are exact
        files.last_mut().unwrap().bytes += total_bytes - per * n_files as u64;
        TransferRequest {
            label: label.into(),
            src,
            dst,
            files,
            concurrency: None,
            verify_checksum: true,
        }
    }
}

/// Outcome for a single file.
#[derive(Debug, Clone)]
pub struct FileReport {
    pub name: String,
    pub bytes: u64,
    pub attempts: u32,
    pub start_vt: f64,
    pub finish_vt: f64,
}

/// Outcome for a whole task.
#[derive(Debug, Clone)]
pub struct TransferReport {
    pub label: String,
    pub src: EndpointId,
    pub dst: EndpointId,
    pub bytes: u64,
    pub concurrency: usize,
    pub start_vt: f64,
    /// when task bookkeeping ends and the data phase begins
    pub data_start_vt: f64,
    /// when the last byte (+checksum) lands
    pub data_end_vt: f64,
    pub finish_vt: f64,
    pub files: Vec<FileReport>,
    /// total bytes re-sent due to injected faults
    pub retried_bytes: u64,
}

impl TransferReport {
    /// Full task duration including submit/detect bookkeeping (what the
    /// Table 1 end-to-end columns see).
    pub fn duration(&self) -> f64 {
        self.finish_vt - self.start_vt
    }

    /// Data-phase duration (handshake + streaming + checksums).
    pub fn data_secs(&self) -> f64 {
        self.data_end_vt - self.data_start_vt
    }

    /// Goodput over the data phase in bytes/second — what a Globus-style
    /// throughput benchmark (Fig. 3) reports.
    pub fn throughput_bps(&self) -> f64 {
        if self.data_secs() <= 0.0 {
            return 0.0;
        }
        self.bytes as f64 / self.data_secs()
    }

    pub fn total_attempts(&self) -> u32 {
        self.files.iter().map(|f| f.attempts).sum()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn split_even_preserves_total() {
        let req = TransferRequest::split_even(
            "t",
            "a#x".into(),
            "b#y".into(),
            1_000_000_007,
            16,
        );
        assert_eq!(req.files.len(), 16);
        assert_eq!(req.total_bytes(), 1_000_000_007);
    }

    #[test]
    fn throughput() {
        let rep = TransferReport {
            label: "t".into(),
            src: "a#x".into(),
            dst: "b#y".into(),
            bytes: 1_000_000,
            concurrency: 4,
            start_vt: 10.0,
            data_start_vt: 10.5,
            data_end_vt: 12.0,
            finish_vt: 13.0,
            files: vec![],
            retried_bytes: 0,
        };
        assert_eq!(rep.duration(), 3.0);
        assert_eq!(rep.data_secs(), 1.5);
        // throughput over the data phase only
        assert!((rep.throughput_bps() - 1_000_000.0 / 1.5).abs() < 1e-9);
    }
}
