//! The transfer service: windowed multi-file WAN transfers with startup
//! costs, per-flow TCP caps, storage limits, checksums, and fault
//! recovery — the Globus Transfer analog (DESIGN.md §2).
//!
//! Throughput behaviour reproduced for Fig. 3:
//! * a single stream is window-limited well below the 10 Gbps NIC
//!   (`per_flow_cap_bps`), so concurrency raises aggregate throughput;
//! * each in-flight file pays a control-channel startup cost, so small
//!   files amortize poorly (the paper's `S` term in `T = x/v + S`);
//! * the aggregate saturates at min(NIC, storage read, storage write).
//!
//! Each task is an exact event loop over per-slot state machines
//! ([`TaskSim`]). Under the discrete-event scheduler (DESIGN.md §3) the
//! service runs **multiple tasks concurrently**: every streaming slot of
//! every active task is a fluid flow, and the per-stream rates are the
//! max-min fair (water-filling) allocation over the WAN links it
//! crosses, the source/destination storage throughputs, and its own TCP
//! window cap. Simultaneous tasks therefore share bandwidth exactly the
//! way `simnet::fluid` shares links. A single active task degenerates to
//! the pre-DES allocation formula — `execute` (the synchronous
//! single-task path) produces bit-identical timings to the old engine.

use anyhow::{bail, Result};

use super::endpoint::{Endpoint, EndpointRegistry};
use super::task::{FileReport, TransferReport, TransferRequest};
use crate::simnet::{FaultModel, LinkId, Topology, VClock};
use crate::util::Rng;

/// Tunables of the transfer fabric.
#[derive(Debug, Clone)]
pub struct TransferParams {
    /// control-channel cost to start one file (listing, auth, open)
    pub per_file_startup_s: f64,
    /// task-level handshake before the first byte, in units of RTT
    pub handshake_rtts: f64,
    /// per-TCP-stream throughput bound from window/BDP limits
    pub per_flow_cap_bps: f64,
    /// destination checksum verification throughput
    pub checksum_bps: f64,
    /// concurrency used when the request does not pin one
    pub auto_concurrency: usize,
    /// task submission overhead (API call, queueing) before work starts
    pub submit_overhead_s: f64,
    /// completion-detection lag (status polling granularity)
    pub completion_detect_s: f64,
}

impl Default for TransferParams {
    fn default() -> Self {
        // Calibrated so the paper topology reproduces Fig. 3's shape:
        // ~0.3 GB/s single-stream, >1 GB/s at concurrency >= 4, saturating
        // at the 10 Gbps NIC / DTN storage.
        TransferParams {
            per_file_startup_s: 0.1,
            handshake_rtts: 2.0,
            per_flow_cap_bps: 2.6e9 / 8.0, // 2.6 Gbit/s per stream
            checksum_bps: 4e9,
            auto_concurrency: 8,
            // Globus-task bookkeeping: a few seconds per task regardless
            // of size — why Table 1 shows 4-5 s to move a 3 MB model
            submit_overhead_s: 1.5,
            completion_detect_s: 2.5,
        }
    }
}

/// Handle for a task submitted to the concurrent fabric.
#[derive(Debug, Clone, Copy, PartialEq, Eq, PartialOrd, Ord, Hash)]
pub struct TransferHandle(pub u64);

#[derive(Debug, Clone, Copy)]
enum SlotState {
    Idle,
    /// paying per-file startup; (file idx, ready time, attempt)
    Starting(usize, f64, u32),
    /// streaming bytes; (file idx, remaining, attempt, fail_at_remaining)
    Streaming(usize, f64, u32, Option<f64>),
    /// waiting out retry backoff; (file idx, until, attempt)
    Backoff(usize, f64, u32),
}

/// One transfer worker: a state machine plus a pipelined prefetch — while
/// a file streams, the control channel prepares the next one (Globus
/// `--pipeline`), hiding per-file startup behind data movement.
#[derive(Debug, Clone, Copy)]
struct Slot {
    state: SlotState,
    /// next file already being set up: (file idx, ready time)
    prefetch: Option<(usize, f64)>,
}

/// Incremental simulation of one transfer task. Driven either to
/// completion by `TransferService::execute` (exclusive fabric) or event
/// by event alongside other tasks under the shared allocation.
struct TaskSim {
    req: TransferRequest,
    route: Vec<LinkId>,
    /// min(route bottleneck, src read, dst write) — the solo aggregate cap
    total_cap: f64,
    read_bps: f64,
    write_bps: f64,
    one_way: f64,
    concurrency: usize,
    start_vt: f64,
    data_start: f64,
    /// task-local frontier of simulated virtual time
    t: f64,
    slots: Vec<Slot>,
    pending: std::collections::VecDeque<usize>,
    reports: Vec<FileReport>,
    /// destination checksums run off-slot (pipelined): (file, done_at)
    checksums: Vec<(usize, f64)>,
    done: usize,
    retried_bytes: u64,
    /// final completion event (data_end + detect) consumed
    delivered: bool,
}

impl TaskSim {
    fn new(svc: &TransferService, now: f64, req: &TransferRequest) -> Result<TaskSim> {
        if req.files.is_empty() {
            bail!("transfer `{}` has no files", req.label);
        }
        let src: Endpoint = svc.endpoints.get(&req.src)?.clone();
        let dst: Endpoint = svc.endpoints.get(&req.dst)?.clone();
        if src.facility == dst.facility {
            bail!("transfer `{}` is intra-facility; use local staging", req.label);
        }
        let route = svc.topo.route(src.facility, dst.facility)?.to_vec();
        let bottleneck = route
            .iter()
            .map(|&l| svc.topo.link(l).capacity_bps)
            .fold(f64::INFINITY, f64::min);
        let total_cap = bottleneck.min(src.read_bps).min(dst.write_bps);
        let rtt = svc.topo.rtt(src.facility, dst.facility)?;
        let one_way = svc.topo.route_latency(src.facility, dst.facility)?;

        let concurrency = req
            .concurrency
            .unwrap_or(svc.params.auto_concurrency)
            .clamp(1, req.files.len());

        let start_vt = now;
        // task submission + handshake (auth + negotiation)
        let data_start = start_vt + svc.params.submit_overhead_s;
        let t = data_start + svc.params.handshake_rtts * rtt;

        let n = req.files.len();
        let reports = req
            .files
            .iter()
            .map(|f| FileReport {
                name: f.name.clone(),
                bytes: f.bytes,
                attempts: 0,
                start_vt: f64::NAN,
                finish_vt: f64::NAN,
            })
            .collect();
        Ok(TaskSim {
            req: req.clone(),
            route,
            total_cap,
            read_bps: src.read_bps,
            write_bps: dst.write_bps,
            one_way,
            concurrency,
            start_vt,
            data_start,
            t,
            slots: (0..concurrency)
                .map(|_| Slot {
                    state: SlotState::Idle,
                    prefetch: None,
                })
                .collect(),
            pending: (0..n).collect(),
            reports,
            checksums: Vec::new(),
            done: 0,
            retried_bytes: 0,
            delivered: false,
        })
    }

    fn work_done(&self) -> bool {
        self.done == self.req.files.len()
    }

    fn data_end(&self) -> f64 {
        self.reports
            .iter()
            .map(|r| r.finish_vt)
            .fold(f64::NEG_INFINITY, f64::max)
    }

    /// Fill idle slots at the task's current time (initial window /
    /// post-drain). Idempotent at a fixed time.
    fn fill_slots(&mut self, startup: f64) {
        if self.work_done() {
            return;
        }
        let t = self.t;
        for slot in self.slots.iter_mut() {
            if matches!(slot.state, SlotState::Idle) {
                let next_file = slot
                    .prefetch
                    .take()
                    .or_else(|| self.pending.pop_front().map(|fi| (fi, t + startup)));
                if let Some((fi, ready)) = next_file {
                    if self.reports[fi].start_vt.is_nan() {
                        self.reports[fi].start_vt = t;
                    }
                    slot.state = SlotState::Starting(fi, ready.max(t), 1);
                }
            }
        }
    }

    fn n_streaming(&self) -> usize {
        self.slots
            .iter()
            .filter(|s| matches!(s.state, SlotState::Streaming(..)))
            .count()
    }

    /// Next internal event given the per-stream `rate`. Once the data
    /// phase is done, the single remaining event is task delivery
    /// (completion detection).
    fn next_event(&self, rate: f64, completion_detect_s: f64) -> f64 {
        if self.work_done() {
            return if self.delivered {
                f64::INFINITY
            } else {
                self.data_end() + completion_detect_s
            };
        }
        let mut next = f64::INFINITY;
        for s in &self.slots {
            let ev = match s.state {
                SlotState::Idle => f64::INFINITY,
                SlotState::Starting(_, ready, _) => ready,
                SlotState::Streaming(_, remaining, _, fail_at) => {
                    // event fires when `remaining` reaches the failure
                    // point (or zero on a clean stream)
                    let to_send = (remaining - fail_at.unwrap_or(0.0)).max(0.0);
                    if rate > 0.0 {
                        self.t + to_send / rate
                    } else {
                        f64::INFINITY
                    }
                }
                SlotState::Backoff(_, until, _) => until,
            };
            next = next.min(ev);
        }
        for &(_, done_at) in &self.checksums {
            next = next.min(done_at);
        }
        next
    }

    /// Advance to time `next` streaming at `rate`, then process every
    /// transition due. `next` earlier than the task's own frontier is a
    /// no-op (another task's event fired first).
    fn advance(
        &mut self,
        next: f64,
        rate: f64,
        params: &TransferParams,
        faults: &FaultModel,
        rng: &mut Rng,
    ) -> Result<()> {
        if self.work_done() {
            if !self.delivered && next >= self.data_end() + params.completion_detect_s {
                self.delivered = true;
            }
            return Ok(());
        }
        if next < self.t {
            // another task's event fired before this task's frontier
            // (fresh task still in submit/handshake): nothing here can
            // have happened yet — evaluating transitions at the frontier
            // would fire zero-offset Starting/Backoff slots early and
            // perturb the fault-RNG draw order
            return Ok(());
        }
        let dt = (next - self.t).max(0.0);

        // advance streams
        for s in self.slots.iter_mut() {
            if let SlotState::Streaming(_, ref mut remaining, _, _) = s.state {
                *remaining -= rate * dt;
            }
        }
        let t = self.t.max(next);
        self.t = t;

        // checksum completions
        let one_way = self.one_way;
        let reports = &mut self.reports;
        let done = &mut self.done;
        self.checksums.retain(|&(fi, done_at)| {
            if done_at <= t + 1e-9 {
                reports[fi].finish_vt = done_at + one_way;
                *done += 1;
                false
            } else {
                true
            }
        });

        // slot transitions at time t
        let startup = params.per_file_startup_s;
        for slot in self.slots.iter_mut() {
            match slot.state {
                SlotState::Starting(fi, ready, attempt) if ready <= t + 1e-9 => {
                    self.reports[fi].attempts = attempt;
                    let bytes = self.req.files[fi].bytes as f64;
                    let fail_at = faults
                        .draw_failure(rng)
                        .map(|frac| bytes * (1.0 - frac));
                    slot.state = SlotState::Streaming(fi, bytes, attempt, fail_at);
                    // pipeline the next file's startup behind this stream
                    if slot.prefetch.is_none() {
                        if let Some(nfi) = self.pending.pop_front() {
                            slot.prefetch = Some((nfi, t + startup));
                        }
                    }
                }
                SlotState::Streaming(fi, remaining, attempt, fail_at) => {
                    let threshold = fail_at.unwrap_or(0.0);
                    // one-byte slack: at large virtual t, `t + dt`
                    // rounding can leave sub-byte residues that would
                    // otherwise stall the event loop (dt rounds to 0)
                    if remaining <= threshold + 1.0 {
                        if fail_at.is_some() {
                            // mid-flight failure: bytes sent so far wasted
                            let sent = self.req.files[fi].bytes as f64 - remaining;
                            self.retried_bytes += sent.max(0.0) as u64;
                            if attempt >= faults.max_attempts {
                                bail!(
                                    "transfer `{}`: file `{}` failed {} times",
                                    self.req.label,
                                    self.req.files[fi].name,
                                    attempt
                                );
                            }
                            slot.state = SlotState::Backoff(
                                fi,
                                t + faults.retry_backoff_s,
                                attempt + 1,
                            );
                        } else {
                            if self.req.verify_checksum {
                                let cksum =
                                    self.req.files[fi].bytes as f64 / params.checksum_bps;
                                self.checksums.push((fi, t + cksum));
                            } else {
                                self.reports[fi].finish_vt = t + self.one_way;
                                self.done += 1;
                            }
                            slot.state = SlotState::Idle; // refilled above
                        }
                    }
                }
                SlotState::Backoff(fi, until, attempt) if until <= t + 1e-9 => {
                    slot.state = SlotState::Starting(fi, t + startup, attempt);
                }
                _ => {}
            }
        }
        Ok(())
    }

    fn report(&self, completion_detect_s: f64) -> TransferReport {
        let data_end = self.data_end();
        TransferReport {
            label: self.req.label.clone(),
            src: self.req.src.clone(),
            dst: self.req.dst.clone(),
            bytes: self.req.total_bytes(),
            concurrency: self.concurrency,
            start_vt: self.start_vt,
            data_start_vt: self.data_start,
            data_end_vt: data_end,
            finish_vt: data_end + completion_detect_s,
            files: self.reports.clone(),
            retried_bytes: self.retried_bytes,
        }
    }
}

struct ActiveTask {
    handle: u64,
    sim: TaskSim,
}

/// Abstract capacity a stream consumes: WAN links, endpoint storage, and
/// its own TCP window — the link set the shared water-filling runs over.
#[derive(Debug, Clone, PartialEq, Eq, PartialOrd, Ord)]
enum CapKey {
    Wan(usize),
    Read(String),
    Write(String),
    Stream(usize, usize),
}

/// The service itself. One instance simulates one fabric.
pub struct TransferService {
    pub topo: Topology,
    pub endpoints: EndpointRegistry,
    pub params: TransferParams,
    pub faults: FaultModel,
    rng: Rng,
    active: Vec<ActiveTask>,
    next_handle: u64,
    /// WAN brownout factor from an active `FaultPlan` degradation
    /// window (DESIGN.md §9): every WAN link's capacity is scaled by
    /// this while the fabric advances. 1.0 = healthy.
    wan_factor: f64,
}

impl TransferService {
    pub fn new(topo: Topology, params: TransferParams, faults: FaultModel, seed: u64) -> Self {
        TransferService {
            topo,
            endpoints: EndpointRegistry::new(),
            params,
            faults,
            rng: Rng::new(seed),
            active: Vec::new(),
            next_handle: 1,
            wan_factor: 1.0,
        }
    }

    /// Apply (or clear, with 1.0) a WAN capacity brownout. Active tasks
    /// are re-water-filled at the next fabric event under the new caps;
    /// the synchronous `execute` path (exclusive single-task, Table 1)
    /// deliberately ignores degradations — fault windows are a campaign
    /// construct.
    pub fn set_wan_factor(&mut self, factor: f64) {
        assert!(
            factor > 0.0 && factor <= 1.0 && factor.is_finite(),
            "wan factor must be in (0, 1], got {factor}"
        );
        self.wan_factor = factor;
    }

    pub fn wan_factor(&self) -> f64 {
        self.wan_factor
    }

    /// Paper fabric: SLAC and ALCF DTNs on the §5.1 topology.
    pub fn paper(seed: u64) -> Self {
        let topo = Topology::paper();
        let slac = topo.facility("slac").unwrap();
        let alcf = topo.facility("alcf").unwrap();
        let mut svc = TransferService::new(topo, TransferParams::default(), FaultModel::none(), seed);
        // DTN storage: reads slightly faster than writes, ALCF's parallel
        // FS slightly faster than SLAC's — gives Fig. 3's direction gap.
        svc.endpoints
            .register(Endpoint {
                id: "slac#dtn".into(),
                facility: slac,
                read_bps: 1.30e9,
                write_bps: 1.10e9,
            })
            .unwrap();
        svc.endpoints
            .register(Endpoint {
                id: "alcf#dtn".into(),
                facility: alcf,
                read_bps: 1.45e9,
                write_bps: 1.25e9,
            })
            .unwrap();
        svc
    }

    /// Submit a task to the concurrent fabric at virtual time `now`.
    /// It advances (sharing bandwidth with every other active task) as
    /// the fabric is driven through `advance_to`.
    pub fn submit_task(&mut self, now: f64, req: &TransferRequest) -> Result<TransferHandle> {
        let sim = TaskSim::new(self, now, req)?;
        let handle = TransferHandle(self.next_handle);
        self.next_handle += 1;
        self.active.push(ActiveTask {
            handle: handle.0,
            sim,
        });
        Ok(handle)
    }

    /// Number of tasks currently in flight on the fabric.
    pub fn active_tasks(&self) -> usize {
        self.active.len()
    }

    /// Per-active-task per-stream rates under the current contention.
    ///
    /// With exactly one active task on a healthy WAN this is the solo
    /// formula the pre-DES engine used — `(total_cap /
    /// n_streaming).min(window)` — so single-tenant runs stay
    /// bit-identical. With several tasks (or a WAN degradation active,
    /// whose scaled link caps the cached solo aggregate cannot see),
    /// every streaming slot becomes a flow in a max-min fair water-fill
    /// over WAN links, shared storage, and per-stream window caps.
    fn current_rates(&self) -> Vec<f64> {
        if self.active.len() == 1 && self.wan_factor == 1.0 {
            let sim = &self.active[0].sim;
            let ns = sim.n_streaming();
            let rate = if ns > 0 {
                (sim.total_cap / ns as f64).min(self.params.per_flow_cap_bps)
            } else {
                0.0
            };
            return vec![rate];
        }
        self.shared_stream_rates()
    }

    fn shared_stream_rates(&self) -> Vec<f64> {
        use std::collections::BTreeMap;
        let mut caps: BTreeMap<CapKey, f64> = BTreeMap::new();
        // one flow per streaming slot: (task idx, route over cap keys)
        let mut flows: Vec<(usize, Vec<CapKey>)> = Vec::new();
        for (ti, a) in self.active.iter().enumerate() {
            let sim = &a.sim;
            let ns = sim.n_streaming();
            if ns == 0 {
                continue;
            }
            let read_key = CapKey::Read(sim.req.src.0.clone());
            let write_key = CapKey::Write(sim.req.dst.0.clone());
            caps.entry(read_key.clone()).or_insert(sim.read_bps);
            caps.entry(write_key.clone()).or_insert(sim.write_bps);
            for &l in &sim.route {
                caps.entry(CapKey::Wan(l.0))
                    .or_insert_with(|| self.topo.link(l).capacity_bps * self.wan_factor);
            }
            for si in 0..ns {
                let stream_key = CapKey::Stream(ti, si);
                caps.insert(stream_key.clone(), self.params.per_flow_cap_bps);
                let mut route = vec![read_key.clone()];
                route.extend(sim.route.iter().map(|l| CapKey::Wan(l.0)));
                route.push(write_key.clone());
                route.push(stream_key);
                flows.push((ti, route));
            }
        }

        // water-fill: repeatedly saturate the link with the smallest
        // fair share (same algorithm as simnet::fluid::max_min_rates)
        let mut remaining = caps;
        let mut rates = vec![0.0; flows.len()];
        let mut unfixed: Vec<usize> = (0..flows.len()).collect();
        while !unfixed.is_empty() {
            let mut best: Option<(CapKey, f64)> = None;
            for (k, &cap) in &remaining {
                let users = unfixed
                    .iter()
                    .filter(|&&f| flows[f].1.contains(k))
                    .count();
                if users == 0 {
                    continue;
                }
                let share = cap / users as f64;
                if best.as_ref().map(|(_, s)| share < *s).unwrap_or(true) {
                    best = Some((k.clone(), share));
                }
            }
            let Some((bottleneck, share)) = best else { break };
            let (fixed, rest): (Vec<usize>, Vec<usize>) = unfixed
                .into_iter()
                .partition(|&f| flows[f].1.contains(&bottleneck));
            for &f in &fixed {
                rates[f] = share;
                for k in &flows[f].1 {
                    if let Some(c) = remaining.get_mut(k) {
                        *c = (*c - share).max(0.0);
                    }
                }
            }
            remaining.remove(&bottleneck);
            unfixed = rest;
        }

        // streams of one task are symmetric: report one per-stream rate
        // per task (zero for tasks with nothing streaming)
        let mut per_task = vec![0.0; self.active.len()];
        for (fi, (ti, _)) in flows.iter().enumerate() {
            per_task[*ti] = rates[fi];
        }
        per_task
    }

    /// Earliest future virtual time the fabric changes state, under the
    /// current allocation. `None` when no task is active.
    pub fn next_event_time(&mut self) -> Option<f64> {
        if self.active.is_empty() {
            return None;
        }
        let startup = self.params.per_file_startup_s;
        for a in &mut self.active {
            a.sim.fill_slots(startup);
        }
        let rates = self.current_rates();
        let detect = self.params.completion_detect_s;
        let mut t = f64::INFINITY;
        for (a, &r) in self.active.iter().zip(&rates) {
            t = t.min(a.sim.next_event(r, detect));
        }
        t.is_finite().then_some(t)
    }

    /// Drive every active task to virtual time `t`, re-solving the
    /// shared allocation at each arrival/completion event. Returns tasks
    /// delivered (or hard-failed) by `t`.
    pub fn advance_to(&mut self, t: f64) -> Vec<(TransferHandle, Result<TransferReport>)> {
        let mut out = Vec::new();
        while !self.active.is_empty() {
            let startup = self.params.per_file_startup_s;
            for a in &mut self.active {
                a.sim.fill_slots(startup);
            }
            let rates = self.current_rates();
            let detect = self.params.completion_detect_s;
            let mut min_t = f64::INFINITY;
            for (a, &r) in self.active.iter().zip(&rates) {
                min_t = min_t.min(a.sim.next_event(r, detect));
            }
            assert!(
                min_t.is_finite(),
                "transfer fabric stalled with {} active task(s)",
                self.active.len()
            );
            let step_t = if min_t <= t { min_t } else { t };
            // advance every task (streams flow between events even when
            // the event belongs to another task)
            let params = &self.params;
            let faults = &self.faults;
            let rng = &mut self.rng;
            let mut failures: Vec<(usize, anyhow::Error)> = Vec::new();
            for (i, (a, &r)) in self.active.iter_mut().zip(&rates).enumerate() {
                if let Err(e) = a.sim.advance(step_t, r, params, faults, rng) {
                    failures.push((i, e));
                }
            }
            // remove hard failures (highest index first)
            for (i, e) in failures.into_iter().rev() {
                let a = self.active.remove(i);
                out.push((TransferHandle(a.handle), Err(e)));
            }
            // collect deliveries
            let detect_s = detect;
            let mut i = 0;
            while i < self.active.len() {
                if self.active[i].sim.delivered {
                    let a = self.active.remove(i);
                    out.push((TransferHandle(a.handle), Ok(a.sim.report(detect_s))));
                } else {
                    i += 1;
                }
            }
            if min_t > t {
                break; // streamed partial progress up to the horizon
            }
        }
        out
    }

    /// Execute a transfer synchronously, advancing the shared virtual
    /// clock to its completion — the exclusive single-task path (Table 1,
    /// Fig. 3). Returns the per-file breakdown.
    pub fn execute(&mut self, clock: &mut VClock, req: &TransferRequest) -> Result<TransferReport> {
        let mut sim = TaskSim::new(self, clock.now(), req)?;
        let startup = self.params.per_file_startup_s;
        while !sim.work_done() {
            sim.fill_slots(startup);
            let n_streaming = sim.n_streaming();
            let rate = if n_streaming > 0 {
                (sim.total_cap / n_streaming as f64).min(self.params.per_flow_cap_bps)
            } else {
                0.0
            };
            let next = sim.next_event(rate, self.params.completion_detect_s);
            assert!(
                next.is_finite(),
                "transfer stalled: {} files pending, slots {:?}",
                sim.pending.len(),
                sim.slots
            );
            sim.advance(next, rate, &self.params, &self.faults, &mut self.rng)?;
        }
        let report = sim.report(self.params.completion_detect_s);
        clock.advance_to(report.finish_vt);
        Ok(report)
    }

    /// Predict a transfer duration with the paper's linear model
    /// `T = x/v + S` (§4.1) without simulating.
    pub fn predict_linear(&self, req: &TransferRequest) -> Result<f64> {
        let src = self.endpoints.get(&req.src)?;
        let dst = self.endpoints.get(&req.dst)?;
        let route = self.topo.route(src.facility, dst.facility)?;
        let bottleneck = route
            .iter()
            .map(|&l| self.topo.link(l).capacity_bps)
            .fold(f64::INFINITY, f64::min);
        let k = req
            .concurrency
            .unwrap_or(self.params.auto_concurrency)
            .clamp(1, req.files.len()) as f64;
        let v = bottleneck
            .min(src.read_bps)
            .min(dst.write_bps)
            .min(self.params.per_flow_cap_bps * k);
        // startups pipeline behind streaming; only the first file's setup
        // (plus any un-hidden residue) is exposed
        let stream_per_file = req.total_bytes() as f64 / req.files.len() as f64 / (v / k);
        let exposed = (self.params.per_file_startup_s - stream_per_file).max(0.0)
            * (req.files.len() as f64 / k - 1.0).max(0.0);
        let s = self.params.handshake_rtts * self.topo.rtt(src.facility, dst.facility)?
            + self.params.per_file_startup_s
            + exposed
            + self.params.submit_overhead_s
            + self.params.completion_detect_s;
        Ok(req.total_bytes() as f64 / v + s)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::transfer::task::TransferRequest;

    fn svc() -> TransferService {
        TransferService::paper(42)
    }

    fn gb_request(n_files: usize, concurrency: Option<usize>) -> TransferRequest {
        let mut r = TransferRequest::split_even(
            "bench",
            "slac#dtn".into(),
            "alcf#dtn".into(),
            1_000_000_000,
            n_files,
        );
        r.concurrency = concurrency;
        r
    }

    #[test]
    fn single_stream_is_window_limited() {
        let mut s = svc();
        let mut clock = VClock::new();
        let rep = s.execute(&mut clock, &gb_request(1, Some(1))).unwrap();
        let gbps = rep.throughput_bps() / 1e9;
        // one TCP stream: ~0.325 GB/s cap, minus startup overheads
        assert!(gbps < 0.33, "single stream too fast: {gbps} GB/s");
        assert!(gbps > 0.25, "single stream too slow: {gbps} GB/s");
        assert_eq!(clock.now(), rep.finish_vt);
    }

    #[test]
    fn concurrency_raises_throughput_until_saturation() {
        let mut last = 0.0;
        let mut tputs = vec![];
        for k in [1usize, 2, 4, 8] {
            let mut s = svc();
            let mut clock = VClock::new();
            let mut req = TransferRequest::split_even(
                "bench",
                "slac#dtn".into(),
                "alcf#dtn".into(),
                4_000_000_000,
                16,
            );
            req.concurrency = Some(k);
            let rep = s.execute(&mut clock, &req).unwrap();
            tputs.push(rep.throughput_bps());
        }
        for (i, &tp) in tputs.iter().enumerate() {
            assert!(tp >= last - 1.0, "throughput dropped at k index {i}: {tputs:?}");
            last = tp;
        }
        // saturates near the SLAC->ALCF cap (min(NIC 1.25, read 1.30,
        // write 1.25) = 1.25 GB/s) within startup overheads
        assert!(tputs[3] > 1.0e9, "saturated throughput {tputs:?}");
    }

    #[test]
    fn direction_asymmetry_matches_fig3() {
        // ALCF->SLAC writes into the slower SLAC store: lower throughput
        let mut s = svc();
        let mut clock = VClock::new();
        let fwd = s.execute(&mut clock, &gb_request(16, Some(8))).unwrap();
        let mut back = TransferRequest::split_even(
            "back",
            "alcf#dtn".into(),
            "slac#dtn".into(),
            1_000_000_000,
            16,
        );
        back.concurrency = Some(8);
        let rep_back = s.execute(&mut clock, &back).unwrap();
        assert!(
            rep_back.throughput_bps() < fwd.throughput_bps(),
            "expected ALCF->SLAC ({}) < SLAC->ALCF ({})",
            rep_back.throughput_bps(),
            fwd.throughput_bps()
        );
    }

    #[test]
    fn faults_cause_retries_and_still_complete() {
        let mut s = svc();
        s.faults = FaultModel::flaky(0.4);
        let mut clock = VClock::new();
        let rep = s.execute(&mut clock, &gb_request(16, Some(4))).unwrap();
        assert!(rep.total_attempts() > 16, "no retries happened");
        assert!(rep.retried_bytes > 0);
        for f in &rep.files {
            assert!(f.finish_vt.is_finite());
        }
        // fault-free run of the same task is faster
        let mut s2 = svc();
        let mut clock2 = VClock::new();
        let clean = s2.execute(&mut clock2, &gb_request(16, Some(4))).unwrap();
        assert!(clean.duration() < rep.duration());
    }

    #[test]
    fn hard_failure_after_max_attempts() {
        let mut s = svc();
        s.faults = FaultModel {
            file_failure_prob: 1.0,
            retry_backoff_s: 0.1,
            max_attempts: 2,
        };
        let mut clock = VClock::new();
        let err = s.execute(&mut clock, &gb_request(2, Some(2)));
        assert!(err.is_err());
        let msg = format!("{:#}", err.err().unwrap());
        assert!(msg.contains("failed 2 times"), "{msg}");
    }

    #[test]
    fn linear_model_tracks_simulation() {
        for k in [1usize, 4, 8] {
            let mut s = svc();
            let mut clock = VClock::new();
            let req = gb_request(16, Some(k));
            let predicted = s.predict_linear(&req).unwrap();
            let rep = s.execute(&mut clock, &req).unwrap();
            let rel = (predicted - rep.duration()).abs() / rep.duration();
            assert!(
                rel < 0.30,
                "k={k}: predicted {predicted:.2}s vs simulated {:.2}s",
                rep.duration()
            );
        }
    }

    #[test]
    fn rejects_degenerate_requests() {
        let mut s = svc();
        let mut clock = VClock::new();
        let empty = TransferRequest {
            label: "e".into(),
            src: "slac#dtn".into(),
            dst: "alcf#dtn".into(),
            files: vec![],
            concurrency: None,
            verify_checksum: false,
        };
        assert!(s.execute(&mut clock, &empty).is_err());
        let unknown = gb_request(1, None);
        let mut unknown = unknown;
        unknown.src = "nowhere#dtn".into();
        assert!(s.execute(&mut clock, &unknown).is_err());
    }

    /// Drive the fabric until a set of handles complete.
    fn drive(
        s: &mut TransferService,
        want: usize,
    ) -> Vec<(TransferHandle, Result<TransferReport>)> {
        let mut done = Vec::new();
        while done.len() < want {
            let t = s.next_event_time().expect("fabric has pending events");
            done.extend(s.advance_to(t));
        }
        done
    }

    /// The N=1 degenerate case of the concurrent fabric must reproduce
    /// the synchronous `execute` path bit for bit — this is what makes
    /// `xloop campaign --users 1` match `xloop table1` exactly.
    #[test]
    fn fabric_single_task_is_bit_identical_to_execute() {
        let mut a = svc();
        let mut clock = VClock::new();
        let rep = a.execute(&mut clock, &gb_request(16, Some(4))).unwrap();

        let mut b = svc();
        let h = b.submit_task(0.0, &gb_request(16, Some(4))).unwrap();
        let mut done = drive(&mut b, 1);
        let (hh, rep2) = done.pop().unwrap();
        let rep2 = rep2.unwrap();
        assert_eq!(hh, h);
        assert_eq!(rep.finish_vt, rep2.finish_vt);
        assert_eq!(rep.data_end_vt, rep2.data_end_vt);
        assert_eq!(rep.data_start_vt, rep2.data_start_vt);
        for (f1, f2) in rep.files.iter().zip(&rep2.files) {
            assert_eq!(f1.start_vt, f2.start_vt, "{}", f1.name);
            assert_eq!(f1.finish_vt, f2.finish_vt, "{}", f1.name);
        }
    }

    /// Satellite acceptance: two simultaneous tasks over the paper
    /// topology each see the max-min fair share (about half the solo
    /// aggregate) and finish later than either would alone.
    #[test]
    fn two_concurrent_tasks_share_bandwidth_max_min() {
        let mut solo = svc();
        let mut clock = VClock::new();
        let alone = solo.execute(&mut clock, &gb_request(16, Some(8))).unwrap();

        let mut s = svc();
        let h1 = s.submit_task(0.0, &gb_request(16, Some(8))).unwrap();
        let h2 = s.submit_task(0.0, &gb_request(16, Some(8))).unwrap();
        assert_eq!(s.active_tasks(), 2);
        let done = drive(&mut s, 2);
        let rep = |h: TransferHandle| {
            done.iter()
                .find(|(hh, _)| *hh == h)
                .unwrap()
                .1
                .as_ref()
                .unwrap()
                .clone()
        };
        let r1 = rep(h1);
        let r2 = rep(h2);

        // both slower than the uncontended task
        assert!(r1.finish_vt > alone.finish_vt, "{} !> {}", r1.finish_vt, alone.finish_vt);
        assert!(r2.finish_vt > alone.finish_vt);
        // identical tasks: symmetric completion
        assert!((r1.finish_vt - r2.finish_vt).abs() < 1e-6, "{r1:?} vs {r2:?}");
        // per-task goodput is the fair share: roughly half the solo
        // aggregate (within startup/checksum overhead effects)
        let half = alone.throughput_bps() / 2.0;
        for r in [&r1, &r2] {
            let tp = r.throughput_bps();
            assert!(
                tp > half * 0.8 && tp < half * 1.2,
                "per-task throughput {tp} not near fair share {half}"
            );
        }
    }

    /// A task arriving mid-flight slows the incumbent down (its finish
    /// moves later than the uncontended run) — bandwidth is re-allocated
    /// at arrival events, like `simnet::fluid` does for raw flows.
    #[test]
    fn late_arrival_reallocates_bandwidth() {
        let mut solo = svc();
        let mut clock = VClock::new();
        // 4 GB so the data phase is long enough to overlap
        let mut big = TransferRequest::split_even(
            "big",
            "slac#dtn".into(),
            "alcf#dtn".into(),
            4_000_000_000,
            16,
        );
        big.concurrency = Some(8);
        let alone = solo.execute(&mut clock, &big).unwrap();

        let mut s = svc();
        let h1 = s.submit_task(0.0, &big).unwrap();
        let h2 = s.submit_task(1.0, &gb_request(16, Some(8))).unwrap();
        let done = drive(&mut s, 2);
        let r1 = done
            .iter()
            .find(|(h, _)| *h == h1)
            .unwrap()
            .1
            .as_ref()
            .unwrap()
            .clone();
        let r2 = done
            .iter()
            .find(|(h, _)| *h == h2)
            .unwrap()
            .1
            .as_ref()
            .unwrap()
            .clone();
        assert!(r1.finish_vt > alone.finish_vt, "incumbent not slowed");
        assert!(r2.finish_vt.is_finite());
    }

    /// A WAN degradation (FaultPlan brownout) slows active transfers:
    /// the water-fill re-runs under the scaled link caps, so the same
    /// task finishes later than on a healthy fabric, and clearing the
    /// factor mid-flight speeds the remainder back up.
    #[test]
    fn wan_degradation_slows_and_recovery_restores() {
        let mut healthy = svc();
        healthy.submit_task(0.0, &gb_request(16, Some(8))).unwrap();
        let base = drive(&mut healthy, 1).pop().unwrap().1.unwrap();

        // degraded for the whole task: strictly slower
        let mut s = svc();
        s.set_wan_factor(0.4);
        s.submit_task(0.0, &gb_request(16, Some(8))).unwrap();
        let slow = drive(&mut s, 1).pop().unwrap().1.unwrap();
        assert!(
            slow.finish_vt > base.finish_vt,
            "degraded {} !> healthy {}",
            slow.finish_vt,
            base.finish_vt
        );

        // degraded only for the first 10 s: between the two
        let mut s = svc();
        s.set_wan_factor(0.4);
        s.submit_task(0.0, &gb_request(16, Some(8))).unwrap();
        let mut done = s.advance_to(10.0);
        assert!(done.is_empty(), "finished during the brownout");
        s.set_wan_factor(1.0);
        while done.is_empty() {
            let t = s.next_event_time().expect("task still active");
            done = s.advance_to(t);
        }
        let mixed = done.pop().unwrap().1.unwrap();
        assert!(mixed.finish_vt > base.finish_vt);
        assert!(mixed.finish_vt < slow.finish_vt);
    }

    #[test]
    #[should_panic]
    fn wan_factor_rejects_out_of_range() {
        let mut s = svc();
        s.set_wan_factor(0.0);
    }

    /// Tasks in opposite directions share the same bidirectional links
    /// in this fabric, but storage caps differ per endpoint; both must
    /// complete and the allocation must never exceed the NIC.
    #[test]
    fn opposite_direction_tasks_complete() {
        let mut s = svc();
        let mut back = TransferRequest::split_even(
            "back",
            "alcf#dtn".into(),
            "slac#dtn".into(),
            1_000_000_000,
            16,
        );
        back.concurrency = Some(8);
        let h1 = s.submit_task(0.0, &gb_request(16, Some(8))).unwrap();
        let h2 = s.submit_task(0.0, &back).unwrap();
        let done = drive(&mut s, 2);
        for (_, r) in &done {
            let r = r.as_ref().unwrap();
            assert!(r.throughput_bps() <= 1.25e9 * 1.001);
            assert!(r.files.iter().all(|f| f.finish_vt.is_finite()));
        }
        assert!(done.iter().any(|(h, _)| *h == h1));
        assert!(done.iter().any(|(h, _)| *h == h2));
    }
}
