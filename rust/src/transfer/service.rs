//! The transfer service: windowed multi-file WAN transfers with startup
//! costs, per-flow TCP caps, storage limits, checksums, and fault
//! recovery — the Globus Transfer analog (DESIGN.md §2).
//!
//! Throughput behaviour reproduced for Fig. 3:
//! * a single stream is window-limited well below the 10 Gbps NIC
//!   (`per_flow_cap_bps`), so concurrency raises aggregate throughput;
//! * each in-flight file pays a control-channel startup cost, so small
//!   files amortize poorly (the paper's `S` term in `T = x/v + S`);
//! * the aggregate saturates at min(NIC, storage read, storage write).
//!
//! Each task is an exact event loop over per-slot state machines
//! ([`TaskSim`]). Under the discrete-event scheduler (DESIGN.md §3) the
//! service runs **multiple tasks concurrently**: every streaming slot of
//! every active task is a fluid flow, and the per-stream rates are the
//! max-min fair (water-filling) allocation over the WAN links it
//! crosses, the source/destination storage throughputs, and its own TCP
//! window cap. Simultaneous tasks therefore share bandwidth exactly the
//! way `simnet::fluid` shares links. A single active task degenerates to
//! the pre-DES allocation formula — `execute` (the synchronous
//! single-task path) produces bit-identical timings to the old engine.

use anyhow::{bail, Result};

use super::endpoint::{Endpoint, EndpointRegistry};
use super::task::{FileReport, TransferReport, TransferRequest};
use crate::simnet::{FaultModel, LinkId, Topology, VClock};
use crate::util::Rng;

/// Tunables of the transfer fabric.
#[derive(Debug, Clone)]
pub struct TransferParams {
    /// control-channel cost to start one file (listing, auth, open)
    pub per_file_startup_s: f64,
    /// task-level handshake before the first byte, in units of RTT
    pub handshake_rtts: f64,
    /// per-TCP-stream throughput bound from window/BDP limits
    pub per_flow_cap_bps: f64,
    /// destination checksum verification throughput
    pub checksum_bps: f64,
    /// concurrency used when the request does not pin one
    pub auto_concurrency: usize,
    /// task submission overhead (API call, queueing) before work starts
    pub submit_overhead_s: f64,
    /// completion-detection lag (status polling granularity)
    pub completion_detect_s: f64,
}

impl Default for TransferParams {
    fn default() -> Self {
        // Calibrated so the paper topology reproduces Fig. 3's shape:
        // ~0.3 GB/s single-stream, >1 GB/s at concurrency >= 4, saturating
        // at the 10 Gbps NIC / DTN storage.
        TransferParams {
            per_file_startup_s: 0.1,
            handshake_rtts: 2.0,
            per_flow_cap_bps: 2.6e9 / 8.0, // 2.6 Gbit/s per stream
            checksum_bps: 4e9,
            auto_concurrency: 8,
            // Globus-task bookkeeping: a few seconds per task regardless
            // of size — why Table 1 shows 4-5 s to move a 3 MB model
            submit_overhead_s: 1.5,
            completion_detect_s: 2.5,
        }
    }
}

/// Handle for a task submitted to the concurrent fabric.
#[derive(Debug, Clone, Copy, PartialEq, Eq, PartialOrd, Ord, Hash)]
pub struct TransferHandle(pub u64);

#[derive(Debug, Clone, Copy)]
enum SlotState {
    Idle,
    /// paying per-file startup; (file idx, ready time, attempt)
    Starting(usize, f64, u32),
    /// streaming bytes; (file idx, remaining, attempt, fail_at_remaining)
    Streaming(usize, f64, u32, Option<f64>),
    /// waiting out retry backoff; (file idx, until, attempt)
    Backoff(usize, f64, u32),
}

/// One transfer worker: a state machine plus a pipelined prefetch — while
/// a file streams, the control channel prepares the next one (Globus
/// `--pipeline`), hiding per-file startup behind data movement.
#[derive(Debug, Clone, Copy)]
struct Slot {
    state: SlotState,
    /// next file already being set up: (file idx, ready time)
    prefetch: Option<(usize, f64)>,
}

/// Incremental simulation of one transfer task. Driven either to
/// completion by `TransferService::execute` (exclusive fabric) or event
/// by event alongside other tasks under the shared allocation.
struct TaskSim {
    req: TransferRequest,
    route: Vec<LinkId>,
    /// min(route bottleneck, src read, dst write) — the solo aggregate cap
    total_cap: f64,
    read_bps: f64,
    write_bps: f64,
    one_way: f64,
    concurrency: usize,
    start_vt: f64,
    data_start: f64,
    /// task-local frontier of simulated virtual time
    t: f64,
    slots: Vec<Slot>,
    pending: std::collections::VecDeque<usize>,
    reports: Vec<FileReport>,
    /// destination checksums run off-slot (pipelined): (file, done_at)
    checksums: Vec<(usize, f64)>,
    done: usize,
    retried_bytes: u64,
    /// final completion event (data_end + detect) consumed
    delivered: bool,
    /// interned shared cap keys in route order (read, WAN links, write);
    /// resolved once at submit, empty on the synchronous `execute` path
    cap_keys: Vec<usize>,
}

impl TaskSim {
    fn new(svc: &TransferService, now: f64, req: &TransferRequest) -> Result<TaskSim> {
        if req.files.is_empty() {
            bail!("transfer `{}` has no files", req.label);
        }
        let src: Endpoint = svc.endpoints.get(&req.src)?.clone();
        let dst: Endpoint = svc.endpoints.get(&req.dst)?.clone();
        if src.facility == dst.facility {
            bail!("transfer `{}` is intra-facility; use local staging", req.label);
        }
        let route = svc.topo.route(src.facility, dst.facility)?.to_vec();
        let bottleneck = route
            .iter()
            .map(|&l| svc.topo.link(l).capacity_bps)
            .fold(f64::INFINITY, f64::min);
        let total_cap = bottleneck.min(src.read_bps).min(dst.write_bps);
        let rtt = svc.topo.rtt(src.facility, dst.facility)?;
        let one_way = svc.topo.route_latency(src.facility, dst.facility)?;

        let concurrency = req
            .concurrency
            .unwrap_or(svc.params.auto_concurrency)
            .clamp(1, req.files.len());

        let start_vt = now;
        // task submission + handshake (auth + negotiation)
        let data_start = start_vt + svc.params.submit_overhead_s;
        let t = data_start + svc.params.handshake_rtts * rtt;

        let n = req.files.len();
        let reports = req
            .files
            .iter()
            .map(|f| FileReport {
                name: f.name.clone(),
                bytes: f.bytes,
                attempts: 0,
                start_vt: f64::NAN,
                finish_vt: f64::NAN,
            })
            .collect();
        Ok(TaskSim {
            req: req.clone(),
            route,
            total_cap,
            read_bps: src.read_bps,
            write_bps: dst.write_bps,
            one_way,
            concurrency,
            start_vt,
            data_start,
            t,
            slots: (0..concurrency)
                .map(|_| Slot {
                    state: SlotState::Idle,
                    prefetch: None,
                })
                .collect(),
            pending: (0..n).collect(),
            reports,
            checksums: Vec::new(),
            done: 0,
            retried_bytes: 0,
            delivered: false,
            cap_keys: Vec::new(),
        })
    }

    fn work_done(&self) -> bool {
        self.done == self.req.files.len()
    }

    fn data_end(&self) -> f64 {
        self.reports
            .iter()
            .map(|r| r.finish_vt)
            .fold(f64::NEG_INFINITY, f64::max)
    }

    /// Fill idle slots at the task's current time (initial window /
    /// post-drain). Idempotent at a fixed time.
    fn fill_slots(&mut self, startup: f64) {
        if self.work_done() {
            return;
        }
        let t = self.t;
        for slot in self.slots.iter_mut() {
            if matches!(slot.state, SlotState::Idle) {
                let next_file = slot
                    .prefetch
                    .take()
                    .or_else(|| self.pending.pop_front().map(|fi| (fi, t + startup)));
                if let Some((fi, ready)) = next_file {
                    if self.reports[fi].start_vt.is_nan() {
                        self.reports[fi].start_vt = t;
                    }
                    slot.state = SlotState::Starting(fi, ready.max(t), 1);
                }
            }
        }
    }

    fn n_streaming(&self) -> usize {
        self.slots
            .iter()
            .filter(|s| matches!(s.state, SlotState::Streaming(..)))
            .count()
    }

    /// Next internal event given the per-stream `rate`. Once the data
    /// phase is done, the single remaining event is task delivery
    /// (completion detection).
    fn next_event(&self, rate: f64, completion_detect_s: f64) -> f64 {
        if self.work_done() {
            return if self.delivered {
                f64::INFINITY
            } else {
                self.data_end() + completion_detect_s
            };
        }
        let mut next = f64::INFINITY;
        for s in &self.slots {
            let ev = match s.state {
                SlotState::Idle => f64::INFINITY,
                SlotState::Starting(_, ready, _) => ready,
                SlotState::Streaming(_, remaining, _, fail_at) => {
                    // event fires when `remaining` reaches the failure
                    // point (or zero on a clean stream)
                    let to_send = (remaining - fail_at.unwrap_or(0.0)).max(0.0);
                    if rate > 0.0 {
                        self.t + to_send / rate
                    } else {
                        f64::INFINITY
                    }
                }
                SlotState::Backoff(_, until, _) => until,
            };
            next = next.min(ev);
        }
        for &(_, done_at) in &self.checksums {
            next = next.min(done_at);
        }
        next
    }

    /// Advance to time `next` streaming at `rate`, then process every
    /// transition due. `next` earlier than the task's own frontier is a
    /// no-op (another task's event fired first).
    fn advance(
        &mut self,
        next: f64,
        rate: f64,
        params: &TransferParams,
        faults: &FaultModel,
        rng: &mut Rng,
    ) -> Result<()> {
        if self.work_done() {
            if !self.delivered && next >= self.data_end() + params.completion_detect_s {
                self.delivered = true;
            }
            return Ok(());
        }
        if next < self.t {
            // another task's event fired before this task's frontier
            // (fresh task still in submit/handshake): nothing here can
            // have happened yet — evaluating transitions at the frontier
            // would fire zero-offset Starting/Backoff slots early and
            // perturb the fault-RNG draw order
            return Ok(());
        }
        let dt = (next - self.t).max(0.0);

        // advance streams
        for s in self.slots.iter_mut() {
            if let SlotState::Streaming(_, ref mut remaining, _, _) = s.state {
                *remaining -= rate * dt;
            }
        }
        let t = self.t.max(next);
        self.t = t;

        // checksum completions
        let one_way = self.one_way;
        let reports = &mut self.reports;
        let done = &mut self.done;
        self.checksums.retain(|&(fi, done_at)| {
            if done_at <= t + 1e-9 {
                reports[fi].finish_vt = done_at + one_way;
                *done += 1;
                false
            } else {
                true
            }
        });

        // slot transitions at time t
        let startup = params.per_file_startup_s;
        for slot in self.slots.iter_mut() {
            match slot.state {
                SlotState::Starting(fi, ready, attempt) if ready <= t + 1e-9 => {
                    self.reports[fi].attempts = attempt;
                    let bytes = self.req.files[fi].bytes as f64;
                    let fail_at = faults
                        .draw_failure(rng)
                        .map(|frac| bytes * (1.0 - frac));
                    slot.state = SlotState::Streaming(fi, bytes, attempt, fail_at);
                    // pipeline the next file's startup behind this stream
                    if slot.prefetch.is_none() {
                        if let Some(nfi) = self.pending.pop_front() {
                            slot.prefetch = Some((nfi, t + startup));
                        }
                    }
                }
                SlotState::Streaming(fi, remaining, attempt, fail_at) => {
                    let threshold = fail_at.unwrap_or(0.0);
                    // one-byte slack: at large virtual t, `t + dt`
                    // rounding can leave sub-byte residues that would
                    // otherwise stall the event loop (dt rounds to 0)
                    if remaining <= threshold + 1.0 {
                        if fail_at.is_some() {
                            // mid-flight failure: bytes sent so far wasted
                            let sent = self.req.files[fi].bytes as f64 - remaining;
                            self.retried_bytes += sent.max(0.0) as u64;
                            if attempt >= faults.max_attempts {
                                bail!(
                                    "transfer `{}`: file `{}` failed {} times",
                                    self.req.label,
                                    self.req.files[fi].name,
                                    attempt
                                );
                            }
                            slot.state = SlotState::Backoff(
                                fi,
                                t + faults.retry_backoff_s,
                                attempt + 1,
                            );
                        } else {
                            if self.req.verify_checksum {
                                let cksum =
                                    self.req.files[fi].bytes as f64 / params.checksum_bps;
                                self.checksums.push((fi, t + cksum));
                            } else {
                                self.reports[fi].finish_vt = t + self.one_way;
                                self.done += 1;
                            }
                            slot.state = SlotState::Idle; // refilled above
                        }
                    }
                }
                SlotState::Backoff(fi, until, attempt) if until <= t + 1e-9 => {
                    slot.state = SlotState::Starting(fi, t + startup, attempt);
                }
                _ => {}
            }
        }
        Ok(())
    }

    fn report(&self, completion_detect_s: f64) -> TransferReport {
        let data_end = self.data_end();
        TransferReport {
            label: self.req.label.clone(),
            src: self.req.src.clone(),
            dst: self.req.dst.clone(),
            bytes: self.req.total_bytes(),
            concurrency: self.concurrency,
            start_vt: self.start_vt,
            data_start_vt: self.data_start,
            data_end_vt: data_end,
            finish_vt: data_end + completion_detect_s,
            files: self.reports.clone(),
            retried_bytes: self.retried_bytes,
        }
    }
}

struct ActiveTask {
    handle: u64,
    sim: TaskSim,
}

/// Abstract capacity a stream consumes: WAN links, endpoint storage, and
/// its own TCP window — the link set the shared water-filling runs over.
/// Used by the reference solver
/// ([`TransferService::shared_stream_rates_reference`]); the production
/// solver works on interned integer ids instead (see [`KeyInterner`]).
#[derive(Debug, Clone, PartialEq, Eq, PartialOrd, Ord)]
enum CapKey {
    Wan(usize),
    Read(String),
    Write(String),
    Stream(usize, usize),
}

/// A shared capacity dimension, interned to a small integer id once per
/// task submit (DESIGN.md §13). The derive order (Wan < Read < Write)
/// mirrors [`CapKey`] minus the per-stream window keys, which always
/// sort after every shared key — the indexed solver iterates candidates
/// in exactly the reference order, so its bottleneck tie-breaks (and
/// therefore its f64 outputs) are bit-identical.
#[derive(Debug, Clone, PartialEq, Eq, PartialOrd, Ord)]
enum KeyKind {
    Wan(usize),
    Read(String),
    Write(String),
}

/// String→id interner for shared cap keys: the water-fill hot loop
/// compares and copies `usize` ids instead of cloning `String`s (the
/// satellite perf fix), and the ids index the per-key flow counters the
/// incremental solver maintains. Each id's static capacity (unscaled by
/// WAN brownouts) is stored alongside; ids are dense and stable for the
/// life of the service.
#[derive(Default)]
struct KeyInterner {
    kinds: Vec<KeyKind>,
    caps: Vec<f64>,
    index: std::collections::BTreeMap<KeyKind, usize>,
}

impl KeyInterner {
    fn intern(&mut self, kind: KeyKind, cap: f64) -> usize {
        if let Some(&id) = self.index.get(&kind) {
            return id;
        }
        let id = self.kinds.len();
        self.kinds.push(kind.clone());
        self.caps.push(cap);
        self.index.insert(kind, id);
        id
    }

    fn len(&self) -> usize {
        self.kinds.len()
    }

    fn is_wan(&self, id: usize) -> bool {
        matches!(self.kinds[id], KeyKind::Wan(_))
    }

    /// Topology link index behind an interned key, if it is a WAN key.
    fn wan_link(&self, id: usize) -> Option<usize> {
        match self.kinds[id] {
            KeyKind::Wan(l) => Some(l),
            _ => None,
        }
    }
}

/// Path-compressing union-find over interned key ids: tasks sharing any
/// capacity dimension land in one contention component, and only the
/// components a join/leave/stream-edge perturbs re-solve.
struct UnionFind {
    parent: Vec<usize>,
}

impl UnionFind {
    fn new(n: usize) -> UnionFind {
        UnionFind {
            parent: (0..n).collect(),
        }
    }

    fn find(&mut self, x: usize) -> usize {
        let mut r = x;
        while self.parent[r] != r {
            r = self.parent[r];
        }
        let mut c = x;
        while self.parent[c] != r {
            let next = self.parent[c];
            self.parent[c] = r;
            c = next;
        }
        r
    }

    fn union(&mut self, a: usize, b: usize) {
        let (ra, rb) = (self.find(a), self.find(b));
        if ra != rb {
            self.parent[ra.max(rb)] = ra.min(rb);
        }
    }
}

/// Per-task allocation from the last shared solve, keyed by task handle
/// so it survives `active` index shifts. A component none of whose keys
/// were perturbed since this snapshot reuses these rates verbatim.
struct RateCache {
    wan_factor: f64,
    tasks: std::collections::BTreeMap<u64, CachedTask>,
}

struct CachedTask {
    ns: usize,
    rate: f64,
    /// the task's interned shared keys — kept so a departure can dirty
    /// the component it used to belong to
    keys: Vec<usize>,
}

/// The service itself. One instance simulates one fabric.
pub struct TransferService {
    pub topo: Topology,
    pub endpoints: EndpointRegistry,
    pub params: TransferParams,
    pub faults: FaultModel,
    rng: Rng,
    active: Vec<ActiveTask>,
    next_handle: u64,
    /// WAN brownout factor from an active `FaultPlan` degradation
    /// window (DESIGN.md §9): every WAN link's capacity is scaled by
    /// this while the fabric advances. 1.0 = healthy.
    wan_factor: f64,
    /// shared-cap-key interner for the indexed water-fill (DESIGN.md §13)
    interner: KeyInterner,
    /// last shared solve, reused for unperturbed contention components
    rate_cache: Option<RateCache>,
    /// bytes streamed through each WAN link (by topology link index)
    /// since the last [`Self::take_wan_window_bytes`] — the bounded-lag
    /// demand ledger (DESIGN.md §14). Pure bookkeeping: never read by
    /// the solver, so fabrics that ignore it behave bit-identically.
    wan_window_bytes: std::collections::BTreeMap<usize, f64>,
}

impl TransferService {
    pub fn new(topo: Topology, params: TransferParams, faults: FaultModel, seed: u64) -> Self {
        TransferService {
            topo,
            endpoints: EndpointRegistry::new(),
            params,
            faults,
            rng: Rng::new(seed),
            active: Vec::new(),
            next_handle: 1,
            wan_factor: 1.0,
            interner: KeyInterner::default(),
            rate_cache: None,
            wan_window_bytes: std::collections::BTreeMap::new(),
        }
    }

    /// Drain the WAN demand ledger: `(topology link index, bytes)`
    /// streamed through each WAN link since the last drain, ascending
    /// by link index. The windowed campaign executor aggregates these
    /// across shards to derive next-window slowdown factors
    /// (DESIGN.md §14); fabrics that never drain just accumulate a map
    /// nobody reads.
    pub fn take_wan_window_bytes(&mut self) -> Vec<(usize, f64)> {
        std::mem::take(&mut self.wan_window_bytes).into_iter().collect()
    }

    /// Apply (or clear, with 1.0) a WAN capacity brownout. Active tasks
    /// are re-water-filled at the next fabric event under the new caps;
    /// the synchronous `execute` path (exclusive single-task, Table 1)
    /// deliberately ignores degradations — fault windows are a campaign
    /// construct.
    pub fn set_wan_factor(&mut self, factor: f64) {
        assert!(
            factor > 0.0 && factor <= 1.0 && factor.is_finite(),
            "wan factor must be in (0, 1], got {factor}"
        );
        if factor != self.wan_factor {
            // every WAN cap changes: no cached component survives
            self.rate_cache = None;
        }
        self.wan_factor = factor;
    }

    pub fn wan_factor(&self) -> f64 {
        self.wan_factor
    }

    /// Paper fabric: SLAC and ALCF DTNs on the §5.1 topology.
    pub fn paper(seed: u64) -> Self {
        let topo = Topology::paper();
        let slac = topo.facility("slac").unwrap();
        let alcf = topo.facility("alcf").unwrap();
        let mut svc = TransferService::new(topo, TransferParams::default(), FaultModel::none(), seed);
        // DTN storage: reads slightly faster than writes, ALCF's parallel
        // FS slightly faster than SLAC's — gives Fig. 3's direction gap.
        svc.endpoints
            .register(Endpoint {
                id: "slac#dtn".into(),
                facility: slac,
                read_bps: 1.30e9,
                write_bps: 1.10e9,
            })
            .unwrap();
        svc.endpoints
            .register(Endpoint {
                id: "alcf#dtn".into(),
                facility: alcf,
                read_bps: 1.45e9,
                write_bps: 1.25e9,
            })
            .unwrap();
        svc
    }

    /// Submit a task to the concurrent fabric at virtual time `now`.
    /// It advances (sharing bandwidth with every other active task) as
    /// the fabric is driven through `advance_to`.
    pub fn submit_task(&mut self, now: f64, req: &TransferRequest) -> Result<TransferHandle> {
        let mut sim = TaskSim::new(self, now, req)?;
        sim.cap_keys = self.intern_task_keys(&sim);
        let handle = TransferHandle(self.next_handle);
        self.next_handle += 1;
        self.active.push(ActiveTask {
            handle: handle.0,
            sim,
        });
        Ok(handle)
    }

    /// Resolve a task's shared cap keys (endpoint id strings, route
    /// links) to interned ids, in route order: read, WAN links, write.
    /// This is the only place strings are touched — every later solve
    /// works on the integer ids.
    fn intern_task_keys(&mut self, sim: &TaskSim) -> Vec<usize> {
        let mut keys = Vec::with_capacity(sim.route.len() + 2);
        keys.push(
            self.interner
                .intern(KeyKind::Read(sim.req.src.0.clone()), sim.read_bps),
        );
        for &l in &sim.route {
            let cap = self.topo.link(l).capacity_bps;
            keys.push(self.interner.intern(KeyKind::Wan(l.0), cap));
        }
        keys.push(
            self.interner
                .intern(KeyKind::Write(sim.req.dst.0.clone()), sim.write_bps),
        );
        keys
    }

    /// Number of tasks currently in flight on the fabric.
    pub fn active_tasks(&self) -> usize {
        self.active.len()
    }

    /// Per-active-task per-stream rates under the current contention.
    ///
    /// With exactly one active task on a healthy WAN this is the solo
    /// formula the pre-DES engine used — `(total_cap /
    /// n_streaming).min(window)` — so single-tenant runs stay
    /// bit-identical. With several tasks (or a WAN degradation active,
    /// whose scaled link caps the cached solo aggregate cannot see),
    /// every streaming slot becomes a flow in a max-min fair water-fill
    /// over WAN links, shared storage, and per-stream window caps.
    fn current_rates(&mut self) -> Vec<f64> {
        if self.active.len() == 1 && self.wan_factor == 1.0 {
            let sim = &self.active[0].sim;
            let ns = sim.n_streaming();
            let rate = if ns > 0 {
                (sim.total_cap / ns as f64).min(self.params.per_flow_cap_bps)
            } else {
                0.0
            };
            return vec![rate];
        }
        self.shared_stream_rates()
    }

    /// Production shared solve (DESIGN.md §13): incremental, component-
    /// scoped, and indexed. Per-key unfixed-flow counters and a
    /// route→key incidence index replace the reference solver's
    /// per-round `contains` scans, and connected components of the
    /// contention graph that no join/leave/stream-edge has perturbed
    /// since the last solve keep their cached rates untouched.
    ///
    /// Bit-identical to [`Self::shared_stream_rates_reference`] — the
    /// per-component bottleneck sequence is the reference's global
    /// sequence restricted to the component (fixes in one component
    /// never touch another's caps), and every arithmetic step runs on
    /// identical values in identical order. Pinned on randomized
    /// fabrics by `incremental_matches_reference_on_randomized_fabrics`.
    fn shared_stream_rates(&mut self) -> Vec<f64> {
        let n = self.active.len();
        let ns: Vec<usize> = self.active.iter().map(|a| a.sim.n_streaming()).collect();

        let mut cache = match self.rate_cache.take() {
            Some(c) if c.wan_factor == self.wan_factor => c,
            _ => RateCache {
                wan_factor: self.wan_factor,
                tasks: Default::default(),
            },
        };

        // keys perturbed since the cached solve: departures (cache
        // remembers the dead task's keys), joins, stream-count edges
        let mut dirty_keys: std::collections::BTreeSet<usize> = Default::default();
        let live: std::collections::BTreeSet<u64> =
            self.active.iter().map(|a| a.handle).collect();
        cache.tasks.retain(|h, ct| {
            let keep = live.contains(h);
            if !keep {
                dirty_keys.extend(ct.keys.iter().copied());
            }
            keep
        });
        for (i, a) in self.active.iter().enumerate() {
            match cache.tasks.get(&a.handle) {
                Some(ct) if ct.ns == ns[i] => {}
                _ => dirty_keys.extend(a.sim.cap_keys.iter().copied()),
            }
        }

        // contention components over interned keys (streaming tasks
        // only — a task with nothing in flight contributes no flows,
        // exactly like the reference solver's `continue`)
        let mut uf = UnionFind::new(self.interner.len());
        for (i, a) in self.active.iter().enumerate() {
            if ns[i] == 0 {
                continue;
            }
            for w in a.sim.cap_keys.windows(2) {
                uf.union(w[0], w[1]);
            }
        }
        let mut comp_tasks: std::collections::BTreeMap<usize, Vec<usize>> = Default::default();
        for (i, a) in self.active.iter().enumerate() {
            if ns[i] > 0 {
                comp_tasks
                    .entry(uf.find(a.sim.cap_keys[0]))
                    .or_default()
                    .push(i);
            }
        }
        let dirty_roots: std::collections::BTreeSet<usize> =
            dirty_keys.iter().map(|&k| uf.find(k)).collect();

        let mut per_task = vec![0.0; n];
        for (&root, tasks) in &comp_tasks {
            if dirty_roots.contains(&root) {
                for (ti, rate) in self.solve_component(tasks, &ns) {
                    per_task[ti] = rate;
                }
            } else {
                for &ti in tasks {
                    per_task[ti] = cache.tasks[&self.active[ti].handle].rate;
                }
            }
        }

        // refresh the cache (keys clone once per task lifetime)
        for (i, a) in self.active.iter().enumerate() {
            cache
                .tasks
                .entry(a.handle)
                .and_modify(|ct| {
                    ct.ns = ns[i];
                    ct.rate = per_task[i];
                })
                .or_insert_with(|| CachedTask {
                    ns: ns[i],
                    rate: per_task[i],
                    keys: a.sim.cap_keys.clone(),
                });
        }
        self.rate_cache = Some(cache);
        per_task
    }

    /// Water-fill one contention component, restricted to `tasks`
    /// (ascending `active` indices). Returns `(task index, per-stream
    /// rate)` pairs, reporting each task's **last** stream — the
    /// reference solver's `per_task[ti] = rates[fi]` overwrite order.
    ///
    /// Candidate order replicates the reference `BTreeMap` exactly:
    /// shared keys in `CapKey` order first, then stream-window keys in
    /// flow order. An unfixed stream's window cap is never subtracted
    /// from (no other flow crosses it), so the stream candidate is
    /// always `per_flow_cap_bps` at the first unfixed flow; it wins a
    /// round only on strict `<`, just as a later `BTreeMap` key only
    /// displaces the incumbent on strict `<`.
    fn solve_component(&self, tasks: &[usize], ns: &[usize]) -> Vec<(usize, f64)> {
        // component key set, iterated in reference (CapKey) order
        let mut key_ids: Vec<usize> = tasks
            .iter()
            .flat_map(|&ti| self.active[ti].sim.cap_keys.iter().copied())
            .collect();
        key_ids.sort_unstable();
        key_ids.dedup();
        key_ids.sort_by(|&a, &b| self.interner.kinds[a].cmp(&self.interner.kinds[b]));
        let local: std::collections::BTreeMap<usize, usize> =
            key_ids.iter().enumerate().map(|(li, &k)| (k, li)).collect();

        let nk = key_ids.len();
        let mut caps: Vec<f64> = key_ids
            .iter()
            .map(|&k| {
                let c = self.interner.caps[k];
                if self.interner.is_wan(k) {
                    c * self.wan_factor
                } else {
                    c
                }
            })
            .collect();
        let mut alive = vec![true; nk];

        // per-task local routes (route order: read, WAN links, write)
        // and per-key unfixed-flow counters
        let routes: Vec<Vec<usize>> = tasks
            .iter()
            .map(|&ti| {
                self.active[ti].sim.cap_keys.iter().map(|k| local[k]).collect()
            })
            .collect();
        let mut users = vec![0usize; nk];
        for (ci, &ti) in tasks.iter().enumerate() {
            for &lk in &routes[ci] {
                users[lk] += ns[ti];
            }
        }

        // flows in reference order: tasks ascending, streams ascending;
        // flows of one task are contiguous
        let mut flow_range = Vec::with_capacity(tasks.len());
        let mut flow_task = Vec::new();
        let mut nf = 0usize;
        for (ci, &ti) in tasks.iter().enumerate() {
            flow_range.push((nf, nf + ns[ti]));
            flow_task.extend(std::iter::repeat_n(ci, ns[ti]));
            nf += ns[ti];
        }
        let mut fixed = vec![false; nf];
        let mut rates = vec![0.0f64; nf];
        let mut unfixed = nf;
        let mut first_unfixed = 0usize;
        let window = self.params.per_flow_cap_bps;

        while unfixed > 0 {
            let mut best: Option<(usize, f64)> = None; // (local key, share)
            for lk in 0..nk {
                if !alive[lk] || users[lk] == 0 {
                    continue;
                }
                let share = caps[lk] / users[lk] as f64;
                if best.map(|(_, s)| share < s).unwrap_or(true) {
                    best = Some((lk, share));
                }
            }
            while first_unfixed < nf && fixed[first_unfixed] {
                first_unfixed += 1;
            }
            let stream_wins = match best {
                Some((_, s)) => window < s,
                None => true,
            };
            if stream_wins {
                // the bottleneck is one stream's own window: fix exactly
                // that flow, like the reference fixing the single flow
                // crossing a `Stream` key
                let f = first_unfixed;
                let ci = flow_task[f];
                rates[f] = window;
                for &lk in &routes[ci] {
                    if alive[lk] {
                        caps[lk] = (caps[lk] - window).max(0.0);
                    }
                    users[lk] -= 1;
                }
                fixed[f] = true;
                unfixed -= 1;
            } else {
                let (bk, share) = best.unwrap();
                // fix every unfixed flow crossing the bottleneck, in
                // flow order, subtracting sequentially per flow exactly
                // like the reference's fixed-flow loop
                for (ci, &(s, e)) in flow_range.iter().enumerate() {
                    if !routes[ci].contains(&bk) {
                        continue;
                    }
                    for f in s..e {
                        if fixed[f] {
                            continue;
                        }
                        rates[f] = share;
                        for &lk in &routes[ci] {
                            if alive[lk] {
                                caps[lk] = (caps[lk] - share).max(0.0);
                            }
                            users[lk] -= 1;
                        }
                        fixed[f] = true;
                        unfixed -= 1;
                    }
                }
                alive[bk] = false;
            }
        }

        tasks
            .iter()
            .zip(&flow_range)
            .map(|(&ti, &(_, e))| (ti, rates[e - 1]))
            .collect()
    }

    /// The original from-scratch water-fill over `CapKey` strings —
    /// kept verbatim as the executable specification the incremental
    /// solver is property-tested against, and as the baseline the
    /// `water-fill` micro benches compare to. Not used on any hot path.
    pub fn shared_stream_rates_reference(&self) -> Vec<f64> {
        use std::collections::BTreeMap;
        let mut caps: BTreeMap<CapKey, f64> = BTreeMap::new();
        // one flow per streaming slot: (task idx, route over cap keys)
        let mut flows: Vec<(usize, Vec<CapKey>)> = Vec::new();
        for (ti, a) in self.active.iter().enumerate() {
            let sim = &a.sim;
            let ns = sim.n_streaming();
            if ns == 0 {
                continue;
            }
            let read_key = CapKey::Read(sim.req.src.0.clone());
            let write_key = CapKey::Write(sim.req.dst.0.clone());
            caps.entry(read_key.clone()).or_insert(sim.read_bps);
            caps.entry(write_key.clone()).or_insert(sim.write_bps);
            for &l in &sim.route {
                caps.entry(CapKey::Wan(l.0))
                    .or_insert_with(|| self.topo.link(l).capacity_bps * self.wan_factor);
            }
            for si in 0..ns {
                let stream_key = CapKey::Stream(ti, si);
                caps.insert(stream_key.clone(), self.params.per_flow_cap_bps);
                let mut route = vec![read_key.clone()];
                route.extend(sim.route.iter().map(|l| CapKey::Wan(l.0)));
                route.push(write_key.clone());
                route.push(stream_key);
                flows.push((ti, route));
            }
        }

        // water-fill: repeatedly saturate the link with the smallest
        // fair share (same algorithm as simnet::fluid::max_min_rates)
        let mut remaining = caps;
        let mut rates = vec![0.0; flows.len()];
        let mut unfixed: Vec<usize> = (0..flows.len()).collect();
        while !unfixed.is_empty() {
            let mut best: Option<(CapKey, f64)> = None;
            for (k, &cap) in &remaining {
                let users = unfixed
                    .iter()
                    .filter(|&&f| flows[f].1.contains(k))
                    .count();
                if users == 0 {
                    continue;
                }
                let share = cap / users as f64;
                if best.as_ref().map(|(_, s)| share < *s).unwrap_or(true) {
                    best = Some((k.clone(), share));
                }
            }
            let Some((bottleneck, share)) = best else { break };
            let (fixed, rest): (Vec<usize>, Vec<usize>) = unfixed
                .into_iter()
                .partition(|&f| flows[f].1.contains(&bottleneck));
            for &f in &fixed {
                rates[f] = share;
                for k in &flows[f].1 {
                    if let Some(c) = remaining.get_mut(k) {
                        *c = (*c - share).max(0.0);
                    }
                }
            }
            remaining.remove(&bottleneck);
            unfixed = rest;
        }

        // streams of one task are symmetric: report one per-stream rate
        // per task (zero for tasks with nothing streaming)
        let mut per_task = vec![0.0; self.active.len()];
        for (fi, (ti, _)) in flows.iter().enumerate() {
            per_task[*ti] = rates[fi];
        }
        per_task
    }

    /// Probe the production (incremental) shared solve — the exact
    /// allocation `advance_to` uses. Public for the `water-fill` micro
    /// benches and the invariant tests.
    pub fn current_shared_rates(&mut self) -> Vec<f64> {
        self.shared_stream_rates()
    }

    /// Drop the incremental solver's cache so the next solve runs cold
    /// — lets benches separate "indexed solve from scratch" from
    /// "cached component reuse".
    pub fn invalidate_rate_cache(&mut self) {
        self.rate_cache = None;
    }

    /// Earliest future virtual time the fabric changes state, under the
    /// current allocation. `None` when no task is active.
    pub fn next_event_time(&mut self) -> Option<f64> {
        if self.active.is_empty() {
            return None;
        }
        let startup = self.params.per_file_startup_s;
        for a in &mut self.active {
            a.sim.fill_slots(startup);
        }
        let rates = self.current_rates();
        let detect = self.params.completion_detect_s;
        let mut t = f64::INFINITY;
        for (a, &r) in self.active.iter().zip(&rates) {
            t = t.min(a.sim.next_event(r, detect));
        }
        t.is_finite().then_some(t)
    }

    /// Drive every active task to virtual time `t`, re-solving the
    /// shared allocation at each arrival/completion event. Returns tasks
    /// delivered (or hard-failed) by `t`.
    pub fn advance_to(&mut self, t: f64) -> Vec<(TransferHandle, Result<TransferReport>)> {
        let mut out = Vec::new();
        while !self.active.is_empty() {
            let startup = self.params.per_file_startup_s;
            for a in &mut self.active {
                a.sim.fill_slots(startup);
            }
            let rates = self.current_rates();
            let detect = self.params.completion_detect_s;
            let mut min_t = f64::INFINITY;
            for (a, &r) in self.active.iter().zip(&rates) {
                min_t = min_t.min(a.sim.next_event(r, detect));
            }
            assert!(
                min_t.is_finite(),
                "transfer fabric stalled with {} active task(s)",
                self.active.len()
            );
            let step_t = if min_t <= t { min_t } else { t };
            // advance every task (streams flow between events even when
            // the event belongs to another task)
            let params = &self.params;
            let faults = &self.faults;
            let rng = &mut self.rng;
            let interner = &self.interner;
            let ledger = &mut self.wan_window_bytes;
            let mut failures: Vec<(usize, anyhow::Error)> = Vec::new();
            for (i, (a, &r)) in self.active.iter_mut().zip(&rates).enumerate() {
                // credit the demand ledger before the state mutates:
                // bytes this task streams over [task frontier, step_t]
                // attributed to every WAN key on its route
                let dt = (step_t - a.sim.t).max(0.0);
                if dt > 0.0 && r > 0.0 {
                    let bytes = r * dt * a.sim.n_streaming() as f64;
                    if bytes > 0.0 {
                        for &k in &a.sim.cap_keys {
                            if let Some(l) = interner.wan_link(k) {
                                *ledger.entry(l).or_insert(0.0) += bytes;
                            }
                        }
                    }
                }
                if let Err(e) = a.sim.advance(step_t, r, params, faults, rng) {
                    failures.push((i, e));
                }
            }
            // remove hard failures (highest index first)
            for (i, e) in failures.into_iter().rev() {
                let a = self.active.remove(i);
                out.push((TransferHandle(a.handle), Err(e)));
            }
            // collect deliveries
            let detect_s = detect;
            let mut i = 0;
            while i < self.active.len() {
                if self.active[i].sim.delivered {
                    let a = self.active.remove(i);
                    out.push((TransferHandle(a.handle), Ok(a.sim.report(detect_s))));
                } else {
                    i += 1;
                }
            }
            if min_t > t {
                break; // streamed partial progress up to the horizon
            }
        }
        out
    }

    /// Execute a transfer synchronously, advancing the shared virtual
    /// clock to its completion — the exclusive single-task path (Table 1,
    /// Fig. 3). Returns the per-file breakdown.
    pub fn execute(&mut self, clock: &mut VClock, req: &TransferRequest) -> Result<TransferReport> {
        let mut sim = TaskSim::new(self, clock.now(), req)?;
        let startup = self.params.per_file_startup_s;
        while !sim.work_done() {
            sim.fill_slots(startup);
            let n_streaming = sim.n_streaming();
            let rate = if n_streaming > 0 {
                (sim.total_cap / n_streaming as f64).min(self.params.per_flow_cap_bps)
            } else {
                0.0
            };
            let next = sim.next_event(rate, self.params.completion_detect_s);
            assert!(
                next.is_finite(),
                "transfer stalled: {} files pending, slots {:?}",
                sim.pending.len(),
                sim.slots
            );
            sim.advance(next, rate, &self.params, &self.faults, &mut self.rng)?;
        }
        let report = sim.report(self.params.completion_detect_s);
        clock.advance_to(report.finish_vt);
        Ok(report)
    }

    /// Predict a transfer duration with the paper's linear model
    /// `T = x/v + S` (§4.1) without simulating.
    pub fn predict_linear(&self, req: &TransferRequest) -> Result<f64> {
        let src = self.endpoints.get(&req.src)?;
        let dst = self.endpoints.get(&req.dst)?;
        let route = self.topo.route(src.facility, dst.facility)?;
        let bottleneck = route
            .iter()
            .map(|&l| self.topo.link(l).capacity_bps)
            .fold(f64::INFINITY, f64::min);
        let k = req
            .concurrency
            .unwrap_or(self.params.auto_concurrency)
            .clamp(1, req.files.len()) as f64;
        let v = bottleneck
            .min(src.read_bps)
            .min(dst.write_bps)
            .min(self.params.per_flow_cap_bps * k);
        // startups pipeline behind streaming; only the first file's setup
        // (plus any un-hidden residue) is exposed
        let stream_per_file = req.total_bytes() as f64 / req.files.len() as f64 / (v / k);
        let exposed = (self.params.per_file_startup_s - stream_per_file).max(0.0)
            * (req.files.len() as f64 / k - 1.0).max(0.0);
        let s = self.params.handshake_rtts * self.topo.rtt(src.facility, dst.facility)?
            + self.params.per_file_startup_s
            + exposed
            + self.params.submit_overhead_s
            + self.params.completion_detect_s;
        Ok(req.total_bytes() as f64 / v + s)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::transfer::task::TransferRequest;

    fn svc() -> TransferService {
        TransferService::paper(42)
    }

    fn gb_request(n_files: usize, concurrency: Option<usize>) -> TransferRequest {
        let mut r = TransferRequest::split_even(
            "bench",
            "slac#dtn".into(),
            "alcf#dtn".into(),
            1_000_000_000,
            n_files,
        );
        r.concurrency = concurrency;
        r
    }

    #[test]
    fn single_stream_is_window_limited() {
        let mut s = svc();
        let mut clock = VClock::new();
        let rep = s.execute(&mut clock, &gb_request(1, Some(1))).unwrap();
        let gbps = rep.throughput_bps() / 1e9;
        // one TCP stream: ~0.325 GB/s cap, minus startup overheads
        assert!(gbps < 0.33, "single stream too fast: {gbps} GB/s");
        assert!(gbps > 0.25, "single stream too slow: {gbps} GB/s");
        assert_eq!(clock.now(), rep.finish_vt);
    }

    #[test]
    fn concurrency_raises_throughput_until_saturation() {
        let mut last = 0.0;
        let mut tputs = vec![];
        for k in [1usize, 2, 4, 8] {
            let mut s = svc();
            let mut clock = VClock::new();
            let mut req = TransferRequest::split_even(
                "bench",
                "slac#dtn".into(),
                "alcf#dtn".into(),
                4_000_000_000,
                16,
            );
            req.concurrency = Some(k);
            let rep = s.execute(&mut clock, &req).unwrap();
            tputs.push(rep.throughput_bps());
        }
        for (i, &tp) in tputs.iter().enumerate() {
            assert!(tp >= last - 1.0, "throughput dropped at k index {i}: {tputs:?}");
            last = tp;
        }
        // saturates near the SLAC->ALCF cap (min(NIC 1.25, read 1.30,
        // write 1.25) = 1.25 GB/s) within startup overheads
        assert!(tputs[3] > 1.0e9, "saturated throughput {tputs:?}");
    }

    #[test]
    fn direction_asymmetry_matches_fig3() {
        // ALCF->SLAC writes into the slower SLAC store: lower throughput
        let mut s = svc();
        let mut clock = VClock::new();
        let fwd = s.execute(&mut clock, &gb_request(16, Some(8))).unwrap();
        let mut back = TransferRequest::split_even(
            "back",
            "alcf#dtn".into(),
            "slac#dtn".into(),
            1_000_000_000,
            16,
        );
        back.concurrency = Some(8);
        let rep_back = s.execute(&mut clock, &back).unwrap();
        assert!(
            rep_back.throughput_bps() < fwd.throughput_bps(),
            "expected ALCF->SLAC ({}) < SLAC->ALCF ({})",
            rep_back.throughput_bps(),
            fwd.throughput_bps()
        );
    }

    #[test]
    fn faults_cause_retries_and_still_complete() {
        let mut s = svc();
        s.faults = FaultModel::flaky(0.4);
        let mut clock = VClock::new();
        let rep = s.execute(&mut clock, &gb_request(16, Some(4))).unwrap();
        assert!(rep.total_attempts() > 16, "no retries happened");
        assert!(rep.retried_bytes > 0);
        for f in &rep.files {
            assert!(f.finish_vt.is_finite());
        }
        // fault-free run of the same task is faster
        let mut s2 = svc();
        let mut clock2 = VClock::new();
        let clean = s2.execute(&mut clock2, &gb_request(16, Some(4))).unwrap();
        assert!(clean.duration() < rep.duration());
    }

    #[test]
    fn hard_failure_after_max_attempts() {
        let mut s = svc();
        s.faults = FaultModel {
            file_failure_prob: 1.0,
            retry_backoff_s: 0.1,
            max_attempts: 2,
        };
        let mut clock = VClock::new();
        let err = s.execute(&mut clock, &gb_request(2, Some(2)));
        assert!(err.is_err());
        let msg = format!("{:#}", err.err().unwrap());
        assert!(msg.contains("failed 2 times"), "{msg}");
    }

    #[test]
    fn linear_model_tracks_simulation() {
        for k in [1usize, 4, 8] {
            let mut s = svc();
            let mut clock = VClock::new();
            let req = gb_request(16, Some(k));
            let predicted = s.predict_linear(&req).unwrap();
            let rep = s.execute(&mut clock, &req).unwrap();
            let rel = (predicted - rep.duration()).abs() / rep.duration();
            assert!(
                rel < 0.30,
                "k={k}: predicted {predicted:.2}s vs simulated {:.2}s",
                rep.duration()
            );
        }
    }

    #[test]
    fn rejects_degenerate_requests() {
        let mut s = svc();
        let mut clock = VClock::new();
        let empty = TransferRequest {
            label: "e".into(),
            src: "slac#dtn".into(),
            dst: "alcf#dtn".into(),
            files: vec![],
            concurrency: None,
            verify_checksum: false,
        };
        assert!(s.execute(&mut clock, &empty).is_err());
        let unknown = gb_request(1, None);
        let mut unknown = unknown;
        unknown.src = "nowhere#dtn".into();
        assert!(s.execute(&mut clock, &unknown).is_err());
    }

    /// Drive the fabric until a set of handles complete.
    fn drive(
        s: &mut TransferService,
        want: usize,
    ) -> Vec<(TransferHandle, Result<TransferReport>)> {
        let mut done = Vec::new();
        while done.len() < want {
            let t = s.next_event_time().expect("fabric has pending events");
            done.extend(s.advance_to(t));
        }
        done
    }

    /// The N=1 degenerate case of the concurrent fabric must reproduce
    /// the synchronous `execute` path bit for bit — this is what makes
    /// `xloop campaign --users 1` match `xloop table1` exactly.
    #[test]
    fn fabric_single_task_is_bit_identical_to_execute() {
        let mut a = svc();
        let mut clock = VClock::new();
        let rep = a.execute(&mut clock, &gb_request(16, Some(4))).unwrap();

        let mut b = svc();
        let h = b.submit_task(0.0, &gb_request(16, Some(4))).unwrap();
        let mut done = drive(&mut b, 1);
        let (hh, rep2) = done.pop().unwrap();
        let rep2 = rep2.unwrap();
        assert_eq!(hh, h);
        assert_eq!(rep.finish_vt, rep2.finish_vt);
        assert_eq!(rep.data_end_vt, rep2.data_end_vt);
        assert_eq!(rep.data_start_vt, rep2.data_start_vt);
        for (f1, f2) in rep.files.iter().zip(&rep2.files) {
            assert_eq!(f1.start_vt, f2.start_vt, "{}", f1.name);
            assert_eq!(f1.finish_vt, f2.finish_vt, "{}", f1.name);
        }
    }

    /// Satellite acceptance: two simultaneous tasks over the paper
    /// topology each see the max-min fair share (about half the solo
    /// aggregate) and finish later than either would alone.
    #[test]
    fn two_concurrent_tasks_share_bandwidth_max_min() {
        let mut solo = svc();
        let mut clock = VClock::new();
        let alone = solo.execute(&mut clock, &gb_request(16, Some(8))).unwrap();

        let mut s = svc();
        let h1 = s.submit_task(0.0, &gb_request(16, Some(8))).unwrap();
        let h2 = s.submit_task(0.0, &gb_request(16, Some(8))).unwrap();
        assert_eq!(s.active_tasks(), 2);
        let done = drive(&mut s, 2);
        let rep = |h: TransferHandle| {
            done.iter()
                .find(|(hh, _)| *hh == h)
                .unwrap()
                .1
                .as_ref()
                .unwrap()
                .clone()
        };
        let r1 = rep(h1);
        let r2 = rep(h2);

        // both slower than the uncontended task
        assert!(r1.finish_vt > alone.finish_vt, "{} !> {}", r1.finish_vt, alone.finish_vt);
        assert!(r2.finish_vt > alone.finish_vt);
        // identical tasks: symmetric completion
        assert!((r1.finish_vt - r2.finish_vt).abs() < 1e-6, "{r1:?} vs {r2:?}");
        // per-task goodput is the fair share: roughly half the solo
        // aggregate (within startup/checksum overhead effects)
        let half = alone.throughput_bps() / 2.0;
        for r in [&r1, &r2] {
            let tp = r.throughput_bps();
            assert!(
                tp > half * 0.8 && tp < half * 1.2,
                "per-task throughput {tp} not near fair share {half}"
            );
        }
    }

    /// The bounded-lag demand ledger: driving a task through the fabric
    /// credits ~its payload to every WAN link on the route, and the
    /// drain is a true take (second drain is empty).
    #[test]
    fn wan_window_ledger_accounts_streamed_bytes() {
        let mut s = svc();
        s.submit_task(0.0, &gb_request(16, Some(8))).unwrap();
        drive(&mut s, 1);
        let ledger = s.take_wan_window_bytes();
        // paper route slac->alcf: 3 WAN links, each carrying the payload
        assert_eq!(ledger.len(), 3, "{ledger:?}");
        for &(_, bytes) in &ledger {
            // within a few % of the 1 GB payload (completion-detect slop)
            assert!(
                (0.95e9..1.10e9).contains(&bytes),
                "link bytes {bytes} far from payload"
            );
        }
        assert!(s.take_wan_window_bytes().is_empty(), "drain must reset");
    }

    /// A task arriving mid-flight slows the incumbent down (its finish
    /// moves later than the uncontended run) — bandwidth is re-allocated
    /// at arrival events, like `simnet::fluid` does for raw flows.
    #[test]
    fn late_arrival_reallocates_bandwidth() {
        let mut solo = svc();
        let mut clock = VClock::new();
        // 4 GB so the data phase is long enough to overlap
        let mut big = TransferRequest::split_even(
            "big",
            "slac#dtn".into(),
            "alcf#dtn".into(),
            4_000_000_000,
            16,
        );
        big.concurrency = Some(8);
        let alone = solo.execute(&mut clock, &big).unwrap();

        let mut s = svc();
        let h1 = s.submit_task(0.0, &big).unwrap();
        let h2 = s.submit_task(1.0, &gb_request(16, Some(8))).unwrap();
        let done = drive(&mut s, 2);
        let r1 = done
            .iter()
            .find(|(h, _)| *h == h1)
            .unwrap()
            .1
            .as_ref()
            .unwrap()
            .clone();
        let r2 = done
            .iter()
            .find(|(h, _)| *h == h2)
            .unwrap()
            .1
            .as_ref()
            .unwrap()
            .clone();
        assert!(r1.finish_vt > alone.finish_vt, "incumbent not slowed");
        assert!(r2.finish_vt.is_finite());
    }

    /// A WAN degradation (FaultPlan brownout) slows active transfers:
    /// the water-fill re-runs under the scaled link caps, so the same
    /// task finishes later than on a healthy fabric, and clearing the
    /// factor mid-flight speeds the remainder back up.
    #[test]
    fn wan_degradation_slows_and_recovery_restores() {
        let mut healthy = svc();
        healthy.submit_task(0.0, &gb_request(16, Some(8))).unwrap();
        let base = drive(&mut healthy, 1).pop().unwrap().1.unwrap();

        // degraded for the whole task: strictly slower
        let mut s = svc();
        s.set_wan_factor(0.4);
        s.submit_task(0.0, &gb_request(16, Some(8))).unwrap();
        let slow = drive(&mut s, 1).pop().unwrap().1.unwrap();
        assert!(
            slow.finish_vt > base.finish_vt,
            "degraded {} !> healthy {}",
            slow.finish_vt,
            base.finish_vt
        );

        // degraded only for the first 10 s: between the two
        let mut s = svc();
        s.set_wan_factor(0.4);
        s.submit_task(0.0, &gb_request(16, Some(8))).unwrap();
        let mut done = s.advance_to(10.0);
        assert!(done.is_empty(), "finished during the brownout");
        s.set_wan_factor(1.0);
        while done.is_empty() {
            let t = s.next_event_time().expect("task still active");
            done = s.advance_to(t);
        }
        let mixed = done.pop().unwrap().1.unwrap();
        assert!(mixed.finish_vt > base.finish_vt);
        assert!(mixed.finish_vt < slow.finish_vt);
    }

    #[test]
    #[should_panic]
    fn wan_factor_rejects_out_of_range() {
        let mut s = svc();
        s.set_wan_factor(0.0);
    }

    /// Three disjoint WAN routes plus reverse directions: a fabric with
    /// several contention components, for the incremental-solver pins.
    fn multi_route_service(seed: u64) -> TransferService {
        let j = crate::util::Json::parse(
            r#"{
            "facilities": ["a", "b", "c", "d", "e", "f"],
            "links": [
                {"name": "nic-a", "gbps": 10.0, "latency_ms": 0.5},
                {"name": "bb-ab", "gbps": 8.0, "latency_ms": 20.0},
                {"name": "nic-b", "gbps": 10.0, "latency_ms": 0.5},
                {"name": "nic-c", "gbps": 12.0, "latency_ms": 0.5},
                {"name": "bb-cd", "gbps": 6.0, "latency_ms": 30.0},
                {"name": "nic-d", "gbps": 12.0, "latency_ms": 0.5},
                {"name": "nic-e", "gbps": 10.0, "latency_ms": 0.5},
                {"name": "bb-ef", "gbps": 9.0, "latency_ms": 10.0},
                {"name": "nic-f", "gbps": 10.0, "latency_ms": 0.5}
            ],
            "routes": [
                {"from": "a", "to": "b", "links": ["nic-a", "bb-ab", "nic-b"]},
                {"from": "c", "to": "d", "links": ["nic-c", "bb-cd", "nic-d"]},
                {"from": "e", "to": "f", "links": ["nic-e", "bb-ef", "nic-f"]}
            ]
        }"#,
        )
        .unwrap();
        let topo = Topology::from_json(&j).unwrap();
        let mut svc =
            TransferService::new(topo, TransferParams::default(), FaultModel::none(), seed);
        for (ep, fac, r, w) in [
            ("a#dtn", "a", 1.30e9, 1.10e9),
            ("b#dtn", "b", 1.45e9, 1.25e9),
            ("c#dtn", "c", 1.60e9, 1.35e9),
            ("d#dtn", "d", 1.20e9, 1.00e9),
            ("e#dtn", "e", 1.50e9, 1.30e9),
            ("f#dtn", "f", 1.40e9, 1.20e9),
        ] {
            let fid = svc.topo.facility(fac).unwrap();
            svc.endpoints
                .register(Endpoint {
                    id: ep.into(),
                    facility: fid,
                    read_bps: r,
                    write_bps: w,
                })
                .unwrap();
        }
        svc
    }

    /// The tentpole invariant: the incremental component-scoped solver
    /// must match the from-scratch reference **bit for bit** at every
    /// fabric event of a randomized multi-route workload — staggered
    /// joins, deliveries (leaves), stream-count edges as slots drain,
    /// and WAN brownout flips that invalidate every cached component.
    #[test]
    fn incremental_matches_reference_on_randomized_fabrics() {
        let pairs = [
            ("a#dtn", "b#dtn"),
            ("b#dtn", "a#dtn"),
            ("c#dtn", "d#dtn"),
            ("d#dtn", "c#dtn"),
            ("e#dtn", "f#dtn"),
        ];
        for seed in 0..4u64 {
            let mut svc = multi_route_service(seed);
            let mut rng = crate::util::Rng::new(0xFA88_11E5 ^ seed);
            let mut submissions: Vec<(f64, TransferRequest)> = (0..10)
                .map(|i| {
                    let (src, dst) = pairs[rng.below(pairs.len())];
                    let files = 1 + rng.below(12);
                    let bytes = 200_000_000 + rng.below(2_000_000_000) as u64;
                    let mut req = TransferRequest::split_even(
                        &format!("t{i}"),
                        src.into(),
                        dst.into(),
                        bytes,
                        files,
                    );
                    req.concurrency = Some(1 + rng.below(6));
                    (rng.f64() * 20.0, req)
                })
                .collect();
            submissions.sort_by(|a, b| a.0.total_cmp(&b.0));
            let total = submissions.len();
            let mut queue = std::collections::VecDeque::from(submissions);

            let mut now = 0.0f64;
            let mut done = 0usize;
            while done < total {
                while queue.front().map(|(t, _)| *t <= now).unwrap_or(false) {
                    let (_, req) = queue.pop_front().unwrap();
                    svc.submit_task(now, &req).unwrap();
                }
                let inc = svc.current_shared_rates();
                let full = svc.shared_stream_rates_reference();
                assert_eq!(inc.len(), full.len());
                for (i, (a, b)) in inc.iter().zip(&full).enumerate() {
                    assert_eq!(
                        a.to_bits(),
                        b.to_bits(),
                        "task {i}: incremental {a} != reference {b} (seed {seed}, t {now})"
                    );
                }
                let next_sub = queue.front().map(|(t, _)| *t);
                let t = match (svc.next_event_time(), next_sub) {
                    (Some(a), Some(b)) => a.min(b),
                    (Some(a), None) => a,
                    (None, Some(b)) => b,
                    (None, None) => break,
                };
                done += svc.advance_to(t).iter().filter(|(_, r)| r.is_ok()).count();
                now = t;
                // brownout edges: global cache invalidation mid-flight
                match rng.below(10) {
                    0 => svc.set_wan_factor(0.3 + 0.6 * rng.f64()),
                    1 => svc.set_wan_factor(1.0),
                    _ => {}
                }
            }
            assert_eq!(done, total, "seed {seed}: not every task delivered");
        }
    }

    /// Tasks in opposite directions share the same bidirectional links
    /// in this fabric, but storage caps differ per endpoint; both must
    /// complete and the allocation must never exceed the NIC.
    #[test]
    fn opposite_direction_tasks_complete() {
        let mut s = svc();
        let mut back = TransferRequest::split_even(
            "back",
            "alcf#dtn".into(),
            "slac#dtn".into(),
            1_000_000_000,
            16,
        );
        back.concurrency = Some(8);
        let h1 = s.submit_task(0.0, &gb_request(16, Some(8))).unwrap();
        let h2 = s.submit_task(0.0, &back).unwrap();
        let done = drive(&mut s, 2);
        for (_, r) in &done {
            let r = r.as_ref().unwrap();
            assert!(r.throughput_bps() <= 1.25e9 * 1.001);
            assert!(r.files.iter().all(|f| f.finish_vt.is_finite()));
        }
        assert!(done.iter().any(|(h, _)| *h == h1));
        assert!(done.iter().any(|(h, _)| *h == h2));
    }
}
