//! The transfer service: windowed multi-file WAN transfers with startup
//! costs, per-flow TCP caps, storage limits, checksums, and fault
//! recovery — the Globus Transfer analog (DESIGN.md §2).
//!
//! Throughput behaviour reproduced for Fig. 3:
//! * a single stream is window-limited well below the 10 Gbps NIC
//!   (`per_flow_cap_bps`), so concurrency raises aggregate throughput;
//! * each in-flight file pays a control-channel startup cost, so small
//!   files amortize poorly (the paper's `S` term in `T = x/v + S`);
//! * the aggregate saturates at min(NIC, storage read, storage write).
//!
//! The simulation is an exact event loop over per-slot state machines,
//! advancing the shared virtual clock.

use anyhow::{bail, Result};

use super::endpoint::{Endpoint, EndpointRegistry};
use super::task::{FileReport, TransferReport, TransferRequest};
use crate::simnet::{FaultModel, Topology, VClock};
use crate::util::Rng;

/// Tunables of the transfer fabric.
#[derive(Debug, Clone)]
pub struct TransferParams {
    /// control-channel cost to start one file (listing, auth, open)
    pub per_file_startup_s: f64,
    /// task-level handshake before the first byte, in units of RTT
    pub handshake_rtts: f64,
    /// per-TCP-stream throughput bound from window/BDP limits
    pub per_flow_cap_bps: f64,
    /// destination checksum verification throughput
    pub checksum_bps: f64,
    /// concurrency used when the request does not pin one
    pub auto_concurrency: usize,
    /// task submission overhead (API call, queueing) before work starts
    pub submit_overhead_s: f64,
    /// completion-detection lag (status polling granularity)
    pub completion_detect_s: f64,
}

impl Default for TransferParams {
    fn default() -> Self {
        // Calibrated so the paper topology reproduces Fig. 3's shape:
        // ~0.3 GB/s single-stream, >1 GB/s at concurrency >= 4, saturating
        // at the 10 Gbps NIC / DTN storage.
        TransferParams {
            per_file_startup_s: 0.1,
            handshake_rtts: 2.0,
            per_flow_cap_bps: 2.6e9 / 8.0, // 2.6 Gbit/s per stream
            checksum_bps: 4e9,
            auto_concurrency: 8,
            // Globus-task bookkeeping: a few seconds per task regardless
            // of size — why Table 1 shows 4-5 s to move a 3 MB model
            submit_overhead_s: 1.5,
            completion_detect_s: 2.5,
        }
    }
}

/// The service itself. One instance simulates one fabric.
pub struct TransferService {
    pub topo: Topology,
    pub endpoints: EndpointRegistry,
    pub params: TransferParams,
    pub faults: FaultModel,
    rng: Rng,
}

#[derive(Debug, Clone, Copy)]
enum SlotState {
    Idle,
    /// paying per-file startup; (file idx, ready time, attempt)
    Starting(usize, f64, u32),
    /// streaming bytes; (file idx, remaining, attempt, fail_at_remaining)
    Streaming(usize, f64, u32, Option<f64>),
    /// waiting out retry backoff; (file idx, until, attempt)
    Backoff(usize, f64, u32),
}

/// One transfer worker: a state machine plus a pipelined prefetch — while
/// a file streams, the control channel prepares the next one (Globus
/// `--pipeline`), hiding per-file startup behind data movement.
#[derive(Debug, Clone, Copy)]
struct Slot {
    state: SlotState,
    /// next file already being set up: (file idx, ready time)
    prefetch: Option<(usize, f64)>,
}

impl TransferService {
    pub fn new(topo: Topology, params: TransferParams, faults: FaultModel, seed: u64) -> Self {
        TransferService {
            topo,
            endpoints: EndpointRegistry::new(),
            params,
            faults,
            rng: Rng::new(seed),
        }
    }

    /// Paper fabric: SLAC and ALCF DTNs on the §5.1 topology.
    pub fn paper(seed: u64) -> Self {
        let topo = Topology::paper();
        let slac = topo.facility("slac").unwrap();
        let alcf = topo.facility("alcf").unwrap();
        let mut svc = TransferService::new(topo, TransferParams::default(), FaultModel::none(), seed);
        // DTN storage: reads slightly faster than writes, ALCF's parallel
        // FS slightly faster than SLAC's — gives Fig. 3's direction gap.
        svc.endpoints
            .register(Endpoint {
                id: "slac#dtn".into(),
                facility: slac,
                read_bps: 1.30e9,
                write_bps: 1.10e9,
            })
            .unwrap();
        svc.endpoints
            .register(Endpoint {
                id: "alcf#dtn".into(),
                facility: alcf,
                read_bps: 1.45e9,
                write_bps: 1.25e9,
            })
            .unwrap();
        svc
    }

    /// Execute a transfer, advancing the shared virtual clock to its
    /// completion. Returns the per-file breakdown.
    pub fn execute(&mut self, clock: &mut VClock, req: &TransferRequest) -> Result<TransferReport> {
        if req.files.is_empty() {
            bail!("transfer `{}` has no files", req.label);
        }
        let src = self.endpoints.get(&req.src)?.clone();
        let dst = self.endpoints.get(&req.dst)?.clone();
        if src.facility == dst.facility {
            bail!("transfer `{}` is intra-facility; use local staging", req.label);
        }
        let route = self.topo.route(src.facility, dst.facility)?;
        let bottleneck = route
            .iter()
            .map(|&l| self.topo.link(l).capacity_bps)
            .fold(f64::INFINITY, f64::min);
        let total_cap = bottleneck.min(src.read_bps).min(dst.write_bps);
        let rtt = self.topo.rtt(src.facility, dst.facility)?;
        let one_way = self.topo.route_latency(src.facility, dst.facility)?;

        let concurrency = req
            .concurrency
            .unwrap_or(self.params.auto_concurrency)
            .clamp(1, req.files.len());

        let start_vt = clock.now();
        // task submission + handshake (auth + negotiation)
        let data_start = start_vt + self.params.submit_overhead_s;
        let mut t = data_start + self.params.handshake_rtts * rtt;

        let n = req.files.len();
        let mut pending: std::collections::VecDeque<usize> = (0..n).collect();
        let mut slots: Vec<Slot> = (0..concurrency)
            .map(|_| Slot {
                state: SlotState::Idle,
                prefetch: None,
            })
            .collect();
        let mut reports: Vec<FileReport> = req
            .files
            .iter()
            .map(|f| FileReport {
                name: f.name.clone(),
                bytes: f.bytes,
                attempts: 0,
                start_vt: f64::NAN,
                finish_vt: f64::NAN,
            })
            .collect();
        // destination checksums run off-slot (pipelined): (file, done_at)
        let mut checksums: Vec<(usize, f64)> = Vec::new();
        let mut done = 0usize;
        let mut retried_bytes = 0u64;
        let startup = self.params.per_file_startup_s;

        while done < n {
            // fill idle slots (initial window / post-drain)
            for slot in slots.iter_mut() {
                if matches!(slot.state, SlotState::Idle) {
                    let next_file = slot.prefetch.take().or_else(|| {
                        pending.pop_front().map(|fi| (fi, t + startup))
                    });
                    if let Some((fi, ready)) = next_file {
                        if reports[fi].start_vt.is_nan() {
                            reports[fi].start_vt = t;
                        }
                        slot.state = SlotState::Starting(fi, ready.max(t), 1);
                    }
                }
            }

            let n_streaming = slots
                .iter()
                .filter(|s| matches!(s.state, SlotState::Streaming(..)))
                .count();
            let rate = if n_streaming > 0 {
                (total_cap / n_streaming as f64).min(self.params.per_flow_cap_bps)
            } else {
                0.0
            };

            // next event time across slots and checksums
            let mut next = f64::INFINITY;
            for s in &slots {
                let ev = match s.state {
                    SlotState::Idle => f64::INFINITY,
                    SlotState::Starting(_, ready, _) => ready,
                    SlotState::Streaming(_, remaining, _, fail_at) => {
                        // event fires when `remaining` reaches the failure
                        // point (or zero on a clean stream)
                        let to_send = (remaining - fail_at.unwrap_or(0.0)).max(0.0);
                        if rate > 0.0 {
                            t + to_send / rate
                        } else {
                            f64::INFINITY
                        }
                    }
                    SlotState::Backoff(_, until, _) => until,
                };
                next = next.min(ev);
            }
            for &(_, done_at) in &checksums {
                next = next.min(done_at);
            }
            assert!(
                next.is_finite(),
                "transfer stalled: {} files pending, slots {slots:?}",
                pending.len()
            );
            let dt = (next - t).max(0.0);

            // advance streams
            for s in slots.iter_mut() {
                if let SlotState::Streaming(_, ref mut remaining, _, _) = s.state {
                    *remaining -= rate * dt;
                }
            }
            t = next;

            // checksum completions
            checksums.retain(|&(fi, done_at)| {
                if done_at <= t + 1e-9 {
                    reports[fi].finish_vt = done_at + one_way;
                    done += 1;
                    false
                } else {
                    true
                }
            });

            // slot transitions at time t
            for slot in slots.iter_mut() {
                match slot.state {
                    SlotState::Starting(fi, ready, attempt) if ready <= t + 1e-9 => {
                        reports[fi].attempts = attempt;
                        let bytes = req.files[fi].bytes as f64;
                        let fail_at = self
                            .faults
                            .draw_failure(&mut self.rng)
                            .map(|frac| bytes * (1.0 - frac));
                        slot.state = SlotState::Streaming(fi, bytes, attempt, fail_at);
                        // pipeline the next file's startup behind this stream
                        if slot.prefetch.is_none() {
                            if let Some(nfi) = pending.pop_front() {
                                slot.prefetch = Some((nfi, t + startup));
                            }
                        }
                    }
                    SlotState::Streaming(fi, remaining, attempt, fail_at) => {
                        let threshold = fail_at.unwrap_or(0.0);
                        // one-byte slack: at large virtual t, `t + dt`
                        // rounding can leave sub-byte residues that would
                        // otherwise stall the event loop (dt rounds to 0)
                        if remaining <= threshold + 1.0 {
                            if fail_at.is_some() {
                                // mid-flight failure: bytes sent so far wasted
                                let sent = req.files[fi].bytes as f64 - remaining;
                                retried_bytes += sent.max(0.0) as u64;
                                if attempt >= self.faults.max_attempts {
                                    bail!(
                                        "transfer `{}`: file `{}` failed {} times",
                                        req.label,
                                        req.files[fi].name,
                                        attempt
                                    );
                                }
                                slot.state = SlotState::Backoff(
                                    fi,
                                    t + self.faults.retry_backoff_s,
                                    attempt + 1,
                                );
                            } else {
                                if req.verify_checksum {
                                    let cksum =
                                        req.files[fi].bytes as f64 / self.params.checksum_bps;
                                    checksums.push((fi, t + cksum));
                                } else {
                                    reports[fi].finish_vt = t + one_way;
                                    done += 1;
                                }
                                slot.state = SlotState::Idle; // refilled above
                            }
                        }
                    }
                    SlotState::Backoff(fi, until, attempt) if until <= t + 1e-9 => {
                        slot.state = SlotState::Starting(fi, t + startup, attempt);
                    }
                    _ => {}
                }
            }
        }

        let data_end = reports
            .iter()
            .map(|r| r.finish_vt)
            .fold(f64::NEG_INFINITY, f64::max);
        let finish = data_end + self.params.completion_detect_s;
        clock.advance_to(finish);

        Ok(TransferReport {
            label: req.label.clone(),
            src: req.src.clone(),
            dst: req.dst.clone(),
            bytes: req.total_bytes(),
            concurrency,
            start_vt,
            data_start_vt: data_start,
            data_end_vt: data_end,
            finish_vt: finish,
            files: reports,
            retried_bytes,
        })
    }

    /// Predict a transfer duration with the paper's linear model
    /// `T = x/v + S` (§4.1) without simulating.
    pub fn predict_linear(&self, req: &TransferRequest) -> Result<f64> {
        let src = self.endpoints.get(&req.src)?;
        let dst = self.endpoints.get(&req.dst)?;
        let route = self.topo.route(src.facility, dst.facility)?;
        let bottleneck = route
            .iter()
            .map(|&l| self.topo.link(l).capacity_bps)
            .fold(f64::INFINITY, f64::min);
        let k = req
            .concurrency
            .unwrap_or(self.params.auto_concurrency)
            .clamp(1, req.files.len()) as f64;
        let v = bottleneck
            .min(src.read_bps)
            .min(dst.write_bps)
            .min(self.params.per_flow_cap_bps * k);
        // startups pipeline behind streaming; only the first file's setup
        // (plus any un-hidden residue) is exposed
        let stream_per_file = req.total_bytes() as f64 / req.files.len() as f64 / (v / k);
        let exposed = (self.params.per_file_startup_s - stream_per_file).max(0.0)
            * (req.files.len() as f64 / k - 1.0).max(0.0);
        let s = self.params.handshake_rtts * self.topo.rtt(src.facility, dst.facility)?
            + self.params.per_file_startup_s
            + exposed
            + self.params.submit_overhead_s
            + self.params.completion_detect_s;
        Ok(req.total_bytes() as f64 / v + s)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::transfer::task::TransferRequest;

    fn svc() -> TransferService {
        TransferService::paper(42)
    }

    fn gb_request(n_files: usize, concurrency: Option<usize>) -> TransferRequest {
        let mut r = TransferRequest::split_even(
            "bench",
            "slac#dtn".into(),
            "alcf#dtn".into(),
            1_000_000_000,
            n_files,
        );
        r.concurrency = concurrency;
        r
    }

    #[test]
    fn single_stream_is_window_limited() {
        let mut s = svc();
        let mut clock = VClock::new();
        let rep = s.execute(&mut clock, &gb_request(1, Some(1))).unwrap();
        let gbps = rep.throughput_bps() / 1e9;
        // one TCP stream: ~0.325 GB/s cap, minus startup overheads
        assert!(gbps < 0.33, "single stream too fast: {gbps} GB/s");
        assert!(gbps > 0.25, "single stream too slow: {gbps} GB/s");
        assert_eq!(clock.now(), rep.finish_vt);
    }

    #[test]
    fn concurrency_raises_throughput_until_saturation() {
        let mut last = 0.0;
        let mut tputs = vec![];
        for k in [1usize, 2, 4, 8] {
            let mut s = svc();
            let mut clock = VClock::new();
            let mut req = TransferRequest::split_even(
                "bench",
                "slac#dtn".into(),
                "alcf#dtn".into(),
                4_000_000_000,
                16,
            );
            req.concurrency = Some(k);
            let rep = s.execute(&mut clock, &req).unwrap();
            tputs.push(rep.throughput_bps());
        }
        for (i, &tp) in tputs.iter().enumerate() {
            assert!(tp >= last - 1.0, "throughput dropped at k index {i}: {tputs:?}");
            last = tp;
        }
        // saturates near the SLAC->ALCF cap (min(NIC 1.25, read 1.30,
        // write 1.25) = 1.25 GB/s) within startup overheads
        assert!(tputs[3] > 1.0e9, "saturated throughput {tputs:?}");
    }

    #[test]
    fn direction_asymmetry_matches_fig3() {
        // ALCF->SLAC writes into the slower SLAC store: lower throughput
        let mut s = svc();
        let mut clock = VClock::new();
        let fwd = s.execute(&mut clock, &gb_request(16, Some(8))).unwrap();
        let mut back = TransferRequest::split_even(
            "back",
            "alcf#dtn".into(),
            "slac#dtn".into(),
            1_000_000_000,
            16,
        );
        back.concurrency = Some(8);
        let rep_back = s.execute(&mut clock, &back).unwrap();
        assert!(
            rep_back.throughput_bps() < fwd.throughput_bps(),
            "expected ALCF->SLAC ({}) < SLAC->ALCF ({})",
            rep_back.throughput_bps(),
            fwd.throughput_bps()
        );
    }

    #[test]
    fn faults_cause_retries_and_still_complete() {
        let mut s = svc();
        s.faults = FaultModel::flaky(0.4);
        let mut clock = VClock::new();
        let rep = s.execute(&mut clock, &gb_request(16, Some(4))).unwrap();
        assert!(rep.total_attempts() > 16, "no retries happened");
        assert!(rep.retried_bytes > 0);
        for f in &rep.files {
            assert!(f.finish_vt.is_finite());
        }
        // fault-free run of the same task is faster
        let mut s2 = svc();
        let mut clock2 = VClock::new();
        let clean = s2.execute(&mut clock2, &gb_request(16, Some(4))).unwrap();
        assert!(clean.duration() < rep.duration());
    }

    #[test]
    fn hard_failure_after_max_attempts() {
        let mut s = svc();
        s.faults = FaultModel {
            file_failure_prob: 1.0,
            retry_backoff_s: 0.1,
            max_attempts: 2,
        };
        let mut clock = VClock::new();
        let err = s.execute(&mut clock, &gb_request(2, Some(2)));
        assert!(err.is_err());
        let msg = format!("{:#}", err.err().unwrap());
        assert!(msg.contains("failed 2 times"), "{msg}");
    }

    #[test]
    fn linear_model_tracks_simulation() {
        for k in [1usize, 4, 8] {
            let mut s = svc();
            let mut clock = VClock::new();
            let req = gb_request(16, Some(k));
            let predicted = s.predict_linear(&req).unwrap();
            let rep = s.execute(&mut clock, &req).unwrap();
            let rel = (predicted - rep.duration()).abs() / rep.duration();
            assert!(
                rel < 0.30,
                "k={k}: predicted {predicted:.2}s vs simulated {:.2}s",
                rep.duration()
            );
        }
    }

    #[test]
    fn rejects_degenerate_requests() {
        let mut s = svc();
        let mut clock = VClock::new();
        let empty = TransferRequest {
            label: "e".into(),
            src: "slac#dtn".into(),
            dst: "alcf#dtn".into(),
            files: vec![],
            concurrency: None,
            verify_checksum: false,
        };
        assert!(s.execute(&mut clock, &empty).is_err());
        let unknown = gb_request(1, None);
        let mut unknown = unknown;
        unknown.src = "nowhere#dtn".into();
        assert!(s.execute(&mut clock, &unknown).is_err());
    }
}
