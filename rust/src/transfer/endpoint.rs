//! Transfer endpoints: named DTN-backed storage locations at facilities.
//!
//! Mirrors Globus endpoint semantics: a transfer names a source and a
//! destination endpoint; each endpoint is bound to a facility (which
//! determines the WAN route) and has storage-side throughput limits that
//! can cap a transfer below the NIC line rate (paper ref [34]:
//! "bottleneck analysis" found storage, not network, often binds).

use std::collections::BTreeMap;

use anyhow::{bail, Context, Result};

use crate::simnet::FacilityId;

/// Endpoint identifier, conventionally `facility#name` ("slac#dtn").
#[derive(Debug, Clone, PartialEq, Eq, PartialOrd, Ord, Hash)]
pub struct EndpointId(pub String);

impl std::fmt::Display for EndpointId {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "{}", self.0)
    }
}

impl From<&str> for EndpointId {
    fn from(s: &str) -> Self {
        EndpointId(s.to_string())
    }
}

/// A registered transfer endpoint.
#[derive(Debug, Clone)]
pub struct Endpoint {
    pub id: EndpointId,
    pub facility: FacilityId,
    /// storage read throughput (bytes/s) when sourcing data
    pub read_bps: f64,
    /// storage write throughput (bytes/s) when receiving data
    pub write_bps: f64,
}

/// Endpoint registry for the transfer service.
#[derive(Debug, Default)]
pub struct EndpointRegistry {
    endpoints: BTreeMap<EndpointId, Endpoint>,
}

impl EndpointRegistry {
    pub fn new() -> Self {
        Self::default()
    }

    pub fn register(&mut self, ep: Endpoint) -> Result<()> {
        if self.endpoints.contains_key(&ep.id) {
            bail!("endpoint `{}` already registered", ep.id);
        }
        self.endpoints.insert(ep.id.clone(), ep);
        Ok(())
    }

    pub fn get(&self, id: &EndpointId) -> Result<&Endpoint> {
        self.endpoints
            .get(id)
            .with_context(|| format!("unknown endpoint `{id}`"))
    }

    pub fn ids(&self) -> Vec<&EndpointId> {
        self.endpoints.keys().collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn ep(id: &str) -> Endpoint {
        Endpoint {
            id: id.into(),
            facility: FacilityId(0),
            read_bps: 1e9,
            write_bps: 1e9,
        }
    }

    #[test]
    fn register_and_lookup() {
        let mut r = EndpointRegistry::new();
        r.register(ep("slac#dtn")).unwrap();
        assert!(r.get(&"slac#dtn".into()).is_ok());
        assert!(r.get(&"alcf#dtn".into()).is_err());
    }

    #[test]
    fn duplicate_rejected() {
        let mut r = EndpointRegistry::new();
        r.register(ep("a#b")).unwrap();
        assert!(r.register(ep("a#b")).is_err());
    }
}
