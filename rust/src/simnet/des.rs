//! Discrete-event scheduler core (DESIGN.md §3, §13).
//!
//! Timestamped events with deterministic tie-breaking: events scheduled
//! for the same virtual instant fire in the order they were scheduled
//! (a monotone sequence number breaks ties), so a multi-tenant
//! simulation replays identically for a given seed no matter how the
//! queue happens to rebalance. The scheduler owns the [`VClock`];
//! popping an event advances it, so time can never run backwards and no
//! component needs write access to the clock to schedule future work.
//!
//! Two queue backends sit behind the same API and the same `(time,
//! seq)` total order (property-tested against each other below):
//!
//! * **Heap** — the original `BinaryHeap`, O(log n) per op. Default,
//!   and bit-identical to every release since the DES landed.
//! * **Wheel** — the [`super::wheel`] calendar queue, O(1) amortized.
//!   What a `--users 1e6` campaign schedules its wake-ups on.
//!
//! Pick explicitly with [`Scheduler::with_backend`], or let
//! [`Scheduler::for_load`] choose from the expected event count — with
//! `XLOOP_DES=wheel|heap` in the environment overriding the heuristic
//! (the CI byte-diff runs both backends over the same campaign).
//!
//! This is the substrate the campaign layer drives N concurrent flow
//! runs on: flow wake-ups, faas queue starts/completions, and transfer
//! fabric re-allocations are all just events here.

use std::cmp::Ordering;
use std::collections::{BTreeSet, BinaryHeap};

use super::clock::VClock;
use super::wheel::Wheel;

/// Above this expected event count [`Scheduler::for_load`] picks the
/// wheel; below it the heap's constant factors win and its bytes are
/// the historical default.
pub const WHEEL_THRESHOLD: usize = 4096;

/// Handle to a scheduled event (for cancellation).
#[derive(Debug, Clone, Copy, PartialEq, Eq, PartialOrd, Ord, Hash)]
pub struct EventId(u64);

/// Queue backend selector (DESIGN.md §13).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum DesBackend {
    /// Binary heap: O(log n), the historical default.
    Heap,
    /// Calendar queue (`simnet::wheel`): O(1) amortized.
    Wheel,
}

impl DesBackend {
    /// Backend forced by `XLOOP_DES` (`wheel` | `heap`), if any. Unknown
    /// values are ignored rather than fatal: a typo should not change
    /// simulation semantics, only (possibly) miss a speedup.
    pub fn from_env() -> Option<DesBackend> {
        match std::env::var("XLOOP_DES").ok()?.to_ascii_lowercase().as_str() {
            "wheel" => Some(DesBackend::Wheel),
            "heap" => Some(DesBackend::Heap),
            _ => None,
        }
    }
}

struct Entry<E> {
    time: f64,
    seq: u64,
    payload: E,
}

// Min-ordering on (time, seq): the heap is a max-heap, so invert.
impl<E> PartialEq for Entry<E> {
    fn eq(&self, other: &Self) -> bool {
        self.seq == other.seq
    }
}
impl<E> Eq for Entry<E> {}
impl<E> PartialOrd for Entry<E> {
    fn partial_cmp(&self, other: &Self) -> Option<Ordering> {
        Some(self.cmp(other))
    }
}
impl<E> Ord for Entry<E> {
    fn cmp(&self, other: &Self) -> Ordering {
        // reversed: smaller (time, seq) = greater priority
        other
            .time
            .total_cmp(&self.time)
            .then_with(|| other.seq.cmp(&self.seq))
    }
}

enum Queue<E> {
    Heap(BinaryHeap<Entry<E>>),
    /// The wheel plus a one-slot stash: `peek_time` on a calendar queue
    /// is destructive (the cursor sweeps), so the next live entry is
    /// popped into the stash and served from there.
    Wheel {
        wheel: Wheel<E>,
        stash: Option<(f64, u64, E)>,
    },
}

/// Event-queue scheduler owning the virtual clock.
pub struct Scheduler<E> {
    clock: VClock,
    queue: Queue<E>,
    /// seqs of events scheduled but not yet fired or cancelled
    pending: BTreeSet<u64>,
    cancelled: BTreeSet<u64>,
    seq: u64,
}

impl<E> Default for Scheduler<E> {
    fn default() -> Self {
        Scheduler::new()
    }
}

impl<E> Scheduler<E> {
    /// Heap-backed scheduler — the historical default.
    pub fn new() -> Scheduler<E> {
        Scheduler::with_backend(DesBackend::Heap)
    }

    pub fn with_backend(backend: DesBackend) -> Scheduler<E> {
        Scheduler {
            clock: VClock::new(),
            queue: match backend {
                DesBackend::Heap => Queue::Heap(BinaryHeap::new()),
                DesBackend::Wheel => Queue::Wheel {
                    wheel: Wheel::new(),
                    stash: None,
                },
            },
            pending: BTreeSet::new(),
            cancelled: BTreeSet::new(),
            seq: 0,
        }
    }

    /// Pick a backend from the expected total event count: the wheel
    /// above [`WHEEL_THRESHOLD`], the heap below. `XLOOP_DES` overrides
    /// the heuristic in either direction.
    pub fn for_load(expected_events: usize) -> Scheduler<E> {
        let backend = DesBackend::from_env().unwrap_or(if expected_events >= WHEEL_THRESHOLD {
            DesBackend::Wheel
        } else {
            DesBackend::Heap
        });
        Scheduler::with_backend(backend)
    }

    /// Which backend this scheduler runs on.
    pub fn backend(&self) -> DesBackend {
        match self.queue {
            Queue::Heap(_) => DesBackend::Heap,
            Queue::Wheel { .. } => DesBackend::Wheel,
        }
    }

    pub fn now(&self) -> f64 {
        self.clock.now()
    }

    pub fn clock(&self) -> &VClock {
        &self.clock
    }

    /// Schedule an event at an absolute virtual time (>= now).
    pub fn schedule_at(&mut self, t: f64, payload: E) -> EventId {
        assert!(
            t.is_finite() && t >= self.clock.now(),
            "event in the past: {} < {}",
            t,
            self.clock.now()
        );
        let id = EventId(self.seq);
        match &mut self.queue {
            Queue::Heap(heap) => heap.push(Entry {
                time: t,
                seq: self.seq,
                payload,
            }),
            Queue::Wheel { wheel, stash } => {
                // the stash was the minimum when it was popped; the new
                // event may undercut it, so return it to the wheel and
                // let the next peek/pop re-derive the minimum
                if let Some((st, ss, sp)) = stash.take() {
                    wheel.schedule(st, ss, sp);
                }
                wheel.schedule(t, self.seq, payload);
            }
        }
        self.pending.insert(self.seq);
        self.seq += 1;
        id
    }

    /// Schedule an event `dt >= 0` seconds from now.
    pub fn schedule_after(&mut self, dt: f64, payload: E) -> EventId {
        assert!(dt >= 0.0 && dt.is_finite(), "bad event delay {dt}");
        self.schedule_at(self.clock.now() + dt, payload)
    }

    /// Cancel a scheduled event. Returns whether it was still pending
    /// (an already-fired or already-cancelled event is a no-op `false`).
    /// Lazy deletion: the entry stays in the queue and is skipped when
    /// it surfaces.
    pub fn cancel(&mut self, id: EventId) -> bool {
        if !self.pending.remove(&id.0) {
            return false;
        }
        self.cancelled.insert(id.0);
        true
    }

    /// Time of the next (non-cancelled) event without popping it.
    pub fn peek_time(&mut self) -> Option<f64> {
        match self.backend() {
            DesBackend::Heap => {
                self.skim_cancelled();
                let Queue::Heap(heap) = &self.queue else {
                    unreachable!()
                };
                heap.peek().map(|e| e.time)
            }
            DesBackend::Wheel => {
                self.fill_stash();
                let Queue::Wheel { stash, .. } = &self.queue else {
                    unreachable!()
                };
                stash.as_ref().map(|&(t, _, _)| t)
            }
        }
    }

    /// Pop the next event, advancing the clock to its time. `None` when
    /// the queue is empty.
    pub fn pop(&mut self) -> Option<(f64, E)> {
        let (t, seq, payload) = match self.backend() {
            DesBackend::Heap => {
                self.skim_cancelled();
                let Queue::Heap(heap) = &mut self.queue else {
                    unreachable!()
                };
                let e = heap.pop()?;
                (e.time, e.seq, e.payload)
            }
            DesBackend::Wheel => {
                self.fill_stash();
                let Queue::Wheel { stash, .. } = &mut self.queue else {
                    unreachable!()
                };
                stash.take()?
            }
        };
        self.pending.remove(&seq);
        self.clock.advance_to(t);
        Some((t, payload))
    }

    /// Pop the next event only if it is due at or before `window_end`
    /// (the bounded-lag barrier primitive, DESIGN.md §14). Returns
    /// `None` both when the queue is empty and when the next event lies
    /// beyond the window — callers distinguish the two with
    /// [`Scheduler::is_empty`]. Never advances the clock past
    /// `window_end`, so a windowed driver can interleave `run_until`
    /// with fabric advances and stay monotone.
    pub fn run_until(&mut self, window_end: f64) -> Option<(f64, E)> {
        match self.peek_time() {
            Some(t) if t <= window_end => self.pop(),
            _ => None,
        }
    }

    pub fn is_empty(&self) -> bool {
        self.pending.is_empty()
    }

    /// Live (scheduled, neither fired nor cancelled) event count. Exact:
    /// cancelled tombstones linger inside the queues but are tracked out
    /// of `pending` the moment they are cancelled.
    pub fn len(&self) -> usize {
        self.pending.len()
    }

    /// Heap backend only: drop cancelled entries off the top.
    fn skim_cancelled(&mut self) {
        let Queue::Heap(heap) = &mut self.queue else {
            return;
        };
        while let Some(e) = heap.peek() {
            if self.cancelled.remove(&e.seq) {
                heap.pop();
            } else {
                break;
            }
        }
    }

    /// Wheel backend only: pop live entries into the stash, discarding
    /// cancelled ones as they surface.
    fn fill_stash(&mut self) {
        let Queue::Wheel { wheel, stash } = &mut self.queue else {
            return;
        };
        // the stash itself may have been cancelled since it was filled
        if let Some((_, seq, _)) = stash {
            if self.cancelled.remove(seq) {
                *stash = None;
            }
        }
        while stash.is_none() {
            match wheel.pop_min() {
                None => break,
                Some((t, seq, payload)) => {
                    if !self.cancelled.remove(&seq) {
                        *stash = Some((t, seq, payload));
                    }
                }
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::Rng;

    #[test]
    fn pops_in_time_order_and_advances_clock() {
        let mut s = Scheduler::new();
        s.schedule_at(5.0, "c");
        s.schedule_at(1.0, "a");
        s.schedule_at(3.0, "b");
        assert_eq!(s.peek_time(), Some(1.0));
        assert_eq!(s.pop(), Some((1.0, "a")));
        assert_eq!(s.now(), 1.0);
        assert_eq!(s.pop(), Some((3.0, "b")));
        assert_eq!(s.pop(), Some((5.0, "c")));
        assert_eq!(s.now(), 5.0);
        assert_eq!(s.pop(), None);
        assert!(s.is_empty());
    }

    #[test]
    fn same_time_events_fire_in_schedule_order() {
        let mut s = Scheduler::new();
        for i in 0..16 {
            s.schedule_at(2.0, i);
        }
        for i in 0..16 {
            assert_eq!(s.pop(), Some((2.0, i)));
        }
    }

    #[test]
    fn schedule_after_is_relative() {
        let mut s = Scheduler::new();
        s.schedule_at(4.0, "later");
        s.schedule_after(1.0, "sooner");
        assert_eq!(s.pop(), Some((1.0, "sooner")));
        // now = 1.0; relative scheduling stacks on the advanced clock
        s.schedule_after(0.5, "mid");
        assert_eq!(s.pop(), Some((1.5, "mid")));
        assert_eq!(s.pop(), Some((4.0, "later")));
    }

    #[test]
    fn cancel_skips_event() {
        let mut s = Scheduler::new();
        let a = s.schedule_at(1.0, "a");
        s.schedule_at(2.0, "b");
        assert!(s.cancel(a));
        assert!(!s.cancel(a)); // double-cancel is a no-op
        assert_eq!(s.len(), 1);
        assert_eq!(s.pop(), Some((2.0, "b")));
    }

    #[test]
    fn cancel_unknown_id_rejected() {
        let mut s = Scheduler::<u32>::new();
        assert!(!s.cancel(EventId(99)));
    }

    #[test]
    fn cancel_of_fired_event_is_a_no_op() {
        let mut s = Scheduler::new();
        let id = s.schedule_at(1.0, "x");
        assert_eq!(s.pop(), Some((1.0, "x")));
        assert!(!s.cancel(id), "fired events cannot be cancelled");
        // and no tombstone lingers
        assert!(s.is_empty());
        assert_eq!(s.len(), 0);
    }

    #[test]
    #[should_panic]
    fn rejects_event_in_the_past() {
        let mut s = Scheduler::new();
        s.schedule_at(5.0, ());
        s.pop();
        s.schedule_at(1.0, ());
    }

    #[test]
    fn interleaved_schedule_and_pop_stays_deterministic() {
        // two "processes" scheduling reactively: the trace must be the
        // same every run (exercise the seq tie-break under rebalancing)
        for backend in [DesBackend::Heap, DesBackend::Wheel] {
            let mut trace = Vec::new();
            let mut s = Scheduler::with_backend(backend);
            s.schedule_at(0.0, (0u32, 0u32));
            s.schedule_at(0.0, (1, 0));
            while let Some((t, (proc_id, step))) = s.pop() {
                trace.push((t, proc_id, step));
                if step < 3 {
                    s.schedule_after(if proc_id == 0 { 1.0 } else { 1.5 }, (proc_id, step + 1));
                }
            }
            assert_eq!(
                trace,
                vec![
                    (0.0, 0, 0),
                    (0.0, 1, 0),
                    (1.0, 0, 1),
                    (1.5, 1, 1),
                    (2.0, 0, 2),
                    (3.0, 1, 2), // scheduled (at t=1.5) before (0,3) was (t=2.0)
                    (3.0, 0, 3),
                    (4.5, 1, 3),
                ],
                "backend {backend:?}"
            );
        }
    }

    #[test]
    fn wheel_scheduler_passes_the_heap_contract_suite() {
        // the fixed-scenario tests above run on the default heap; rerun
        // the cancellation contract on the wheel explicitly
        let mut s = Scheduler::with_backend(DesBackend::Wheel);
        let a = s.schedule_at(1.0, "a");
        let b = s.schedule_at(2.0, "b");
        s.schedule_at(2.0, "c");
        assert!(s.cancel(a));
        assert!(!s.cancel(a));
        assert_eq!(s.len(), 2);
        assert_eq!(s.peek_time(), Some(2.0));
        // cancel an event that is already sitting in the peek stash
        assert!(s.cancel(b));
        assert_eq!(s.pop(), Some((2.0, "c")));
        assert_eq!(s.pop(), None);
        assert!(s.is_empty());
    }

    /// The tentpole equivalence pin: drive a heap scheduler and a wheel
    /// scheduler through the same randomized op sequence — schedules on
    /// a coarse grid (forcing exact same-instant ties), interleaved
    /// cancellations (including of already-fired events), peeks, and
    /// pops — and require identical traces, ids, lens, and clocks.
    #[test]
    fn wheel_matches_heap_on_randomized_schedules() {
        for seed in 0..12u64 {
            let mut rng = Rng::new(0xD35C_0DE5 ^ seed);
            let mut heap = Scheduler::with_backend(DesBackend::Heap);
            let mut wheel = Scheduler::with_backend(DesBackend::Wheel);
            let mut ids: Vec<(EventId, EventId)> = Vec::new();
            let mut tag = 0u32;
            for _ in 0..3000 {
                match rng.below(10) {
                    // schedule (grid times so distinct ops collide exactly;
                    // 1-in-8 lands ~100x out — the far-horizon population
                    // the two-level wheel keeps out of its near ring)
                    0..=4 => {
                        let grid = rng.below(64) as f64 * 0.25;
                        let dt = if rng.below(8) == 0 { grid * 100.0 } else { grid };
                        let t = heap.now() + dt;
                        let ih = heap.schedule_at(t, tag);
                        let iw = wheel.schedule_at(t, tag);
                        assert_eq!(ih, iw);
                        ids.push((ih, iw));
                        tag += 1;
                    }
                    // cancel a random (possibly fired) id
                    5..=6 => {
                        if !ids.is_empty() {
                            let (ih, iw) = ids[rng.below(ids.len())];
                            assert_eq!(heap.cancel(ih), wheel.cancel(iw));
                        }
                    }
                    // peek
                    7 => assert_eq!(heap.peek_time(), wheel.peek_time()),
                    // pop
                    _ => {
                        assert_eq!(heap.pop(), wheel.pop());
                        assert_eq!(heap.now(), wheel.now());
                    }
                }
                assert_eq!(heap.len(), wheel.len());
            }
            // drain both to the end
            loop {
                let (h, w) = (heap.pop(), wheel.pop());
                assert_eq!(h, w);
                if h.is_none() {
                    break;
                }
            }
            assert!(heap.is_empty() && wheel.is_empty());
        }
    }

    #[test]
    fn run_until_respects_the_window_on_both_backends() {
        for backend in [DesBackend::Heap, DesBackend::Wheel] {
            let mut s = Scheduler::with_backend(backend);
            s.schedule_at(1.0, "a");
            s.schedule_at(2.0, "b");
            s.schedule_at(5.0, "c");
            // events inside the window pop in order...
            assert_eq!(s.run_until(2.0), Some((1.0, "a")), "backend {backend:?}");
            assert_eq!(s.run_until(2.0), Some((2.0, "b")), "backend {backend:?}");
            // ...the one beyond it stays put and the clock does not move
            assert_eq!(s.run_until(2.0), None, "backend {backend:?}");
            assert_eq!(s.now(), 2.0);
            assert!(!s.is_empty(), "pause, not exhaustion");
            // widening the window releases it; an exact-boundary event fires
            assert_eq!(s.run_until(5.0), Some((5.0, "c")), "backend {backend:?}");
            // empty queue: None again, now distinguishable via is_empty
            assert_eq!(s.run_until(100.0), None);
            assert!(s.is_empty());
        }
    }

    #[test]
    fn run_until_window_sweep_equals_unwindowed_trace() {
        // popping through many narrow windows must produce exactly the
        // trace a plain pop loop does (the sync-wan bit-identity pin at
        // the scheduler level)
        for backend in [DesBackend::Heap, DesBackend::Wheel] {
            let mut rng = Rng::new(0xB0B5_11D5);
            let times: Vec<f64> = (0..200).map(|_| rng.below(400) as f64 * 0.125).collect();
            let mut plain = Scheduler::with_backend(backend);
            let mut windowed = Scheduler::with_backend(backend);
            for (i, &t) in times.iter().enumerate() {
                plain.schedule_at(t, i);
                windowed.schedule_at(t, i);
            }
            let mut want = Vec::new();
            while let Some(ev) = plain.pop() {
                want.push(ev);
            }
            let mut got = Vec::new();
            let mut window_end = 0.0;
            while !windowed.is_empty() {
                while let Some(ev) = windowed.run_until(window_end) {
                    got.push(ev);
                }
                window_end += 1.0;
            }
            assert_eq!(got, want, "backend {backend:?}");
        }
    }

    #[test]
    fn for_load_heuristic_picks_by_event_count() {
        // NOTE: asserts the heuristic, so it must not run with XLOOP_DES
        // set — the CI determinism matrix leaves it unset.
        if std::env::var("XLOOP_DES").is_ok() {
            return;
        }
        assert_eq!(Scheduler::<()>::for_load(8).backend(), DesBackend::Heap);
        assert_eq!(
            Scheduler::<()>::for_load(WHEEL_THRESHOLD).backend(),
            DesBackend::Wheel
        );
    }
}
