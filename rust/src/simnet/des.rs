//! Discrete-event scheduler core (DESIGN.md §3).
//!
//! A binary heap of timestamped events with deterministic tie-breaking:
//! events scheduled for the same virtual instant fire in the order they
//! were scheduled (a monotone sequence number breaks heap ties), so a
//! multi-tenant simulation replays identically for a given seed no
//! matter how the heap happens to rebalance. The scheduler owns the
//! [`VClock`]; popping an event advances it, so time can never run
//! backwards and no component needs write access to the clock to
//! schedule future work.
//!
//! This is the substrate the campaign layer drives N concurrent flow
//! runs on: flow wake-ups, faas queue starts/completions, and transfer
//! fabric re-allocations are all just events here.

use std::cmp::Ordering;
use std::collections::{BTreeSet, BinaryHeap};

use super::clock::VClock;

/// Handle to a scheduled event (for cancellation).
#[derive(Debug, Clone, Copy, PartialEq, Eq, PartialOrd, Ord, Hash)]
pub struct EventId(u64);

struct Entry<E> {
    time: f64,
    seq: u64,
    payload: E,
}

// Min-ordering on (time, seq): the heap is a max-heap, so invert.
impl<E> PartialEq for Entry<E> {
    fn eq(&self, other: &Self) -> bool {
        self.seq == other.seq
    }
}
impl<E> Eq for Entry<E> {}
impl<E> PartialOrd for Entry<E> {
    fn partial_cmp(&self, other: &Self) -> Option<Ordering> {
        Some(self.cmp(other))
    }
}
impl<E> Ord for Entry<E> {
    fn cmp(&self, other: &Self) -> Ordering {
        // reversed: smaller (time, seq) = greater priority
        other
            .time
            .total_cmp(&self.time)
            .then_with(|| other.seq.cmp(&self.seq))
    }
}

/// Event-queue scheduler owning the virtual clock.
pub struct Scheduler<E> {
    clock: VClock,
    heap: BinaryHeap<Entry<E>>,
    /// seqs of events scheduled but not yet fired or cancelled
    pending: BTreeSet<u64>,
    cancelled: BTreeSet<u64>,
    seq: u64,
}

impl<E> Default for Scheduler<E> {
    fn default() -> Self {
        Scheduler::new()
    }
}

impl<E> Scheduler<E> {
    pub fn new() -> Scheduler<E> {
        Scheduler {
            clock: VClock::new(),
            heap: BinaryHeap::new(),
            pending: BTreeSet::new(),
            cancelled: BTreeSet::new(),
            seq: 0,
        }
    }

    pub fn now(&self) -> f64 {
        self.clock.now()
    }

    pub fn clock(&self) -> &VClock {
        &self.clock
    }

    /// Schedule an event at an absolute virtual time (>= now).
    pub fn schedule_at(&mut self, t: f64, payload: E) -> EventId {
        assert!(
            t.is_finite() && t >= self.clock.now(),
            "event in the past: {} < {}",
            t,
            self.clock.now()
        );
        let id = EventId(self.seq);
        self.heap.push(Entry {
            time: t,
            seq: self.seq,
            payload,
        });
        self.pending.insert(self.seq);
        self.seq += 1;
        id
    }

    /// Schedule an event `dt >= 0` seconds from now.
    pub fn schedule_after(&mut self, dt: f64, payload: E) -> EventId {
        assert!(dt >= 0.0 && dt.is_finite(), "bad event delay {dt}");
        self.schedule_at(self.clock.now() + dt, payload)
    }

    /// Cancel a scheduled event. Returns whether it was still pending
    /// (an already-fired or already-cancelled event is a no-op `false`).
    /// Lazy deletion: the entry stays in the heap and is skipped at pop.
    pub fn cancel(&mut self, id: EventId) -> bool {
        if !self.pending.remove(&id.0) {
            return false;
        }
        self.cancelled.insert(id.0);
        true
    }

    /// Time of the next (non-cancelled) event without popping it.
    pub fn peek_time(&mut self) -> Option<f64> {
        self.skim_cancelled();
        self.heap.peek().map(|e| e.time)
    }

    /// Pop the next event, advancing the clock to its time. `None` when
    /// the queue is empty.
    pub fn pop(&mut self) -> Option<(f64, E)> {
        self.skim_cancelled();
        let e = self.heap.pop()?;
        self.pending.remove(&e.seq);
        self.clock.advance_to(e.time);
        Some((e.time, e.payload))
    }

    pub fn is_empty(&mut self) -> bool {
        self.skim_cancelled();
        self.heap.is_empty()
    }

    pub fn len(&mut self) -> usize {
        // cancelled tombstones may linger deeper in the heap; only the
        // top is guaranteed live, so count conservatively
        self.skim_cancelled();
        self.heap.len() - self
            .heap
            .iter()
            .filter(|e| self.cancelled.contains(&e.seq))
            .count()
    }

    fn skim_cancelled(&mut self) {
        while let Some(e) = self.heap.peek() {
            if self.cancelled.remove(&e.seq) {
                self.heap.pop();
            } else {
                break;
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn pops_in_time_order_and_advances_clock() {
        let mut s = Scheduler::new();
        s.schedule_at(5.0, "c");
        s.schedule_at(1.0, "a");
        s.schedule_at(3.0, "b");
        assert_eq!(s.peek_time(), Some(1.0));
        assert_eq!(s.pop(), Some((1.0, "a")));
        assert_eq!(s.now(), 1.0);
        assert_eq!(s.pop(), Some((3.0, "b")));
        assert_eq!(s.pop(), Some((5.0, "c")));
        assert_eq!(s.now(), 5.0);
        assert_eq!(s.pop(), None);
        assert!(s.is_empty());
    }

    #[test]
    fn same_time_events_fire_in_schedule_order() {
        let mut s = Scheduler::new();
        for i in 0..16 {
            s.schedule_at(2.0, i);
        }
        for i in 0..16 {
            assert_eq!(s.pop(), Some((2.0, i)));
        }
    }

    #[test]
    fn schedule_after_is_relative() {
        let mut s = Scheduler::new();
        s.schedule_at(4.0, "later");
        s.schedule_after(1.0, "sooner");
        assert_eq!(s.pop(), Some((1.0, "sooner")));
        // now = 1.0; relative scheduling stacks on the advanced clock
        s.schedule_after(0.5, "mid");
        assert_eq!(s.pop(), Some((1.5, "mid")));
        assert_eq!(s.pop(), Some((4.0, "later")));
    }

    #[test]
    fn cancel_skips_event() {
        let mut s = Scheduler::new();
        let a = s.schedule_at(1.0, "a");
        s.schedule_at(2.0, "b");
        assert!(s.cancel(a));
        assert!(!s.cancel(a)); // double-cancel is a no-op
        assert_eq!(s.len(), 1);
        assert_eq!(s.pop(), Some((2.0, "b")));
    }

    #[test]
    fn cancel_unknown_id_rejected() {
        let mut s = Scheduler::<u32>::new();
        assert!(!s.cancel(EventId(99)));
    }

    #[test]
    fn cancel_of_fired_event_is_a_no_op() {
        let mut s = Scheduler::new();
        let id = s.schedule_at(1.0, "x");
        assert_eq!(s.pop(), Some((1.0, "x")));
        assert!(!s.cancel(id), "fired events cannot be cancelled");
        // and no tombstone lingers
        assert!(s.is_empty());
        assert_eq!(s.len(), 0);
    }

    #[test]
    #[should_panic]
    fn rejects_event_in_the_past() {
        let mut s = Scheduler::new();
        s.schedule_at(5.0, ());
        s.pop();
        s.schedule_at(1.0, ());
    }

    #[test]
    fn interleaved_schedule_and_pop_stays_deterministic() {
        // two "processes" scheduling reactively: the trace must be the
        // same every run (exercise the seq tie-break under rebalancing)
        let mut trace = Vec::new();
        let mut s = Scheduler::new();
        s.schedule_at(0.0, (0u32, 0u32));
        s.schedule_at(0.0, (1, 0));
        while let Some((t, (proc_id, step))) = s.pop() {
            trace.push((t, proc_id, step));
            if step < 3 {
                s.schedule_after(if proc_id == 0 { 1.0 } else { 1.5 }, (proc_id, step + 1));
            }
        }
        assert_eq!(
            trace,
            vec![
                (0.0, 0, 0),
                (0.0, 1, 0),
                (1.0, 0, 1),
                (1.5, 1, 1),
                (2.0, 0, 2),
                (3.0, 1, 2), // scheduled (at t=1.5) before (0,3) was (t=2.0)
                (3.0, 0, 3),
                (4.5, 1, 3),
            ]
        );
    }
}
