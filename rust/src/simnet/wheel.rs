//! Calendar-queue event backend (DESIGN.md §13).
//!
//! A classic Brown calendar queue: one "year" of fixed-width time
//! buckets, a virtual-bucket cursor (`epoch`) that sweeps forward, and
//! entries hashed into `bucket = vk % n_buckets` where
//! `vk = floor(time / width)`. Schedule is an O(1) push; pop scans the
//! cursor bucket for entries belonging to the current epoch and takes
//! the `(time, seq)` minimum, advancing the cursor over empty buckets.
//! With the width resized to track the mean inter-event gap the queue
//! holds ~one live event per bucket, making both operations O(1)
//! amortized — against O(log n) for the binary heap — which is what a
//! million-user campaign needs from its wake-up queue.
//!
//! Correctness invariant: **every stored entry has `vk >= epoch`.**
//! Pop preserves it by construction (it only advances `epoch` past
//! buckets holding no current-epoch entry); schedule restores it by
//! rewinding `epoch` when a new entry lands earlier than the cursor
//! (legal: the cursor may have swept ahead of wall-clock `now` while
//! scanning toward a far-future event). Bucket membership and epoch
//! eligibility use the *identical* float expression
//! `(t / width).floor()`, so an entry can never be hashed into a bucket
//! the eligibility test disagrees with.
//!
//! The wheel stores raw `(time, seq, payload)` triples; cancellation
//! bookkeeping (the pending/cancelled sets) stays in
//! [`super::des::Scheduler`], which lazily discards cancelled seqs as
//! they surface. Total order popped: ascending `(time, seq)` — the
//! exact tie-break contract of the heap backend, property-tested
//! against it in `simnet::des`.

/// Smallest bucket count; also the grow/shrink floor.
const MIN_BUCKETS: usize = 16;
/// Gap samples taken when re-picking the bucket width on resize.
const WIDTH_SAMPLES: usize = 64;

struct Entry<E> {
    time: f64,
    seq: u64,
    payload: E,
}

pub(crate) struct Wheel<E> {
    buckets: Vec<Vec<Entry<E>>>,
    /// bucket width in virtual seconds (> 0)
    width: f64,
    /// virtual bucket cursor: no stored entry has `vk < epoch`
    epoch: u64,
    len: usize,
}

impl<E> Wheel<E> {
    pub(crate) fn new() -> Wheel<E> {
        Wheel {
            buckets: (0..MIN_BUCKETS).map(|_| Vec::new()).collect(),
            width: 1.0,
            epoch: 0,
            len: 0,
        }
    }

    pub(crate) fn len(&self) -> usize {
        self.len
    }

    /// Virtual bucket index of a timestamp. Times are clamped at zero:
    /// the scheduler's clock starts non-negative and never runs
    /// backwards, so negative times cannot reach us, but a clamp is
    /// cheaper than an unreachable panic path.
    #[inline]
    fn vk(&self, t: f64) -> u64 {
        (t.max(0.0) / self.width).floor() as u64
    }

    pub(crate) fn schedule(&mut self, time: f64, seq: u64, payload: E) {
        if self.len >= self.buckets.len() * 2 {
            self.resize(self.buckets.len() * 2);
        }
        let vk = self.vk(time);
        // restore the invariant if the cursor swept past this slot
        if vk < self.epoch {
            self.epoch = vk;
        }
        let n = self.buckets.len() as u64;
        self.buckets[(vk % n) as usize].push(Entry { time, seq, payload });
        self.len += 1;
    }

    /// Remove and return the globally minimum `(time, seq)` entry.
    pub(crate) fn pop_min(&mut self) -> Option<(f64, u64, E)> {
        if self.len == 0 {
            return None;
        }
        let n = self.buckets.len() as u64;
        let mut scanned = 0u64;
        loop {
            let b = (self.epoch % n) as usize;
            let mut best: Option<usize> = None;
            for (i, e) in self.buckets[b].iter().enumerate() {
                if self.vk(e.time) != self.epoch {
                    continue; // a collision from a later revolution
                }
                best = Some(match best {
                    None => i,
                    Some(j) => {
                        let bj = &self.buckets[b][j];
                        if e.time.total_cmp(&bj.time).then(e.seq.cmp(&bj.seq)).is_lt() {
                            i
                        } else {
                            j
                        }
                    }
                });
            }
            if let Some(i) = best {
                return Some(self.take(b, i));
            }
            // empty virtual bucket: commit the cursor forward (this is
            // where the O(1) amortization comes from — each empty bucket
            // is crossed once, not re-scanned on every pop)
            self.epoch += 1;
            scanned += 1;
            if scanned >= n {
                // a full revolution without a hit: the next event is more
                // than a year ahead of the cursor. Jump straight to it.
                return Some(self.pop_global_min());
            }
        }
    }

    /// Fallback for sparse far-future schedules: linear scan of every
    /// bucket for the global `(time, seq)` minimum, jumping the cursor
    /// to its epoch. O(n + len), amortized away by the resize policy.
    fn pop_global_min(&mut self) -> (f64, u64, E) {
        debug_assert!(self.len > 0);
        let mut at: Option<(usize, usize)> = None;
        for (b, bucket) in self.buckets.iter().enumerate() {
            for (i, e) in bucket.iter().enumerate() {
                let better = match at {
                    None => true,
                    Some((pb, pi)) => {
                        let p = &self.buckets[pb][pi];
                        e.time.total_cmp(&p.time).then(e.seq.cmp(&p.seq)).is_lt()
                    }
                };
                if better {
                    at = Some((b, i));
                }
            }
        }
        let (b, i) = at.expect("non-empty wheel has a minimum");
        self.epoch = self.vk(self.buckets[b][i].time);
        self.take(b, i)
    }

    fn take(&mut self, bucket: usize, i: usize) -> (f64, u64, E) {
        let e = self.buckets[bucket].swap_remove(i);
        self.len -= 1;
        if self.len < self.buckets.len() / 4 && self.buckets.len() > MIN_BUCKETS {
            self.resize((self.buckets.len() / 2).max(MIN_BUCKETS));
        }
        (e.time, e.seq, e.payload)
    }

    /// Rebuild with `n_new` buckets, re-picking the width from the mean
    /// gap of a sample of stored times so occupancy stays ~1 per bucket.
    fn resize(&mut self, n_new: usize) {
        let entries: Vec<Entry<E>> = self.buckets.iter_mut().flat_map(std::mem::take).collect();
        if let Some(w) = sample_width(&entries) {
            self.width = w;
        }
        self.buckets = (0..n_new).map(|_| Vec::new()).collect();
        // the cursor currently points at time ~ epoch * old_width; with a
        // new width the cheapest correct cursor is the minimum stored vk
        // (pop only requires that no entry precede the cursor)
        self.epoch = entries.iter().map(|e| self.vk(e.time)).min().unwrap_or(0);
        let n = n_new as u64;
        for e in entries {
            let vk = self.vk(e.time);
            self.buckets[(vk % n) as usize].push(e);
        }
    }
}

/// Mean positive gap between up-to-[`WIDTH_SAMPLES`] sorted sampled
/// times, clamped to a sane range. `None` when the sample carries no
/// signal (fewer than two distinct times).
fn sample_width<E>(entries: &[Entry<E>]) -> Option<f64> {
    if entries.len() < 2 {
        return None;
    }
    let stride = (entries.len() / WIDTH_SAMPLES).max(1);
    let mut times: Vec<f64> = entries.iter().step_by(stride).map(|e| e.time).collect();
    times.sort_by(f64::total_cmp);
    let gaps: Vec<f64> = times.windows(2).map(|w| w[1] - w[0]).filter(|&g| g > 0.0).collect();
    if gaps.is_empty() {
        return None;
    }
    let mean = gaps.iter().sum::<f64>() / gaps.len() as f64;
    // classic calendar-queue practice: a bucket spans a few mean gaps
    Some((mean * 2.0).clamp(1e-6, 1e9))
}

#[cfg(test)]
mod tests {
    use super::*;

    fn drain(w: &mut Wheel<u32>) -> Vec<(f64, u64)> {
        let mut out = Vec::new();
        while let Some((t, s, _)) = w.pop_min() {
            out.push((t, s));
        }
        out
    }

    #[test]
    fn pops_in_time_then_seq_order() {
        let mut w = Wheel::new();
        w.schedule(3.0, 0, 0);
        w.schedule(1.0, 1, 0);
        w.schedule(1.0, 2, 0);
        w.schedule(0.5, 3, 0);
        assert_eq!(drain(&mut w), vec![(0.5, 3), (1.0, 1), (1.0, 2), (3.0, 0)]);
        assert_eq!(w.len(), 0);
    }

    #[test]
    fn far_future_event_found_via_global_fallback() {
        let mut w = Wheel::new();
        // more than a full revolution (16 buckets * 1 s) ahead
        w.schedule(1e7, 0, 7);
        assert_eq!(w.pop_min(), Some((1e7, 0, 7)));
    }

    #[test]
    fn schedule_behind_swept_cursor_is_still_found() {
        let mut w = Wheel::new();
        // sweep the cursor far forward by popping a far-future event
        w.schedule(1000.0, 0, 0);
        assert!(w.pop_min().is_some());
        // a later schedule into an earlier virtual bucket (legal: the
        // >= now guard is the Scheduler's business, and `peek_time` can
        // sweep the cursor past `now`) must rewind the cursor so the
        // entry stays visible
        w.schedule(500.0, 1, 1);
        w.schedule(1000.5, 2, 2);
        assert_eq!(w.epoch, 500);
        assert_eq!(drain(&mut w), vec![(500.0, 1), (1000.5, 2)]);
    }

    #[test]
    fn grows_and_shrinks_through_resize() {
        let mut w = Wheel::new();
        for i in 0..4096u64 {
            w.schedule(i as f64 * 0.125, i, i as u32);
        }
        assert!(w.buckets.len() > MIN_BUCKETS);
        let order = drain(&mut w);
        assert_eq!(order.len(), 4096);
        assert!(order.windows(2).all(|p| p[0] <= p[1]), "out of order");
        assert_eq!(w.buckets.len(), MIN_BUCKETS);
    }

    #[test]
    fn identical_times_resize_without_width_signal() {
        // all-equal times give sample_width nothing; the resize must
        // keep the old width and stay correct
        let mut w = Wheel::new();
        for i in 0..256u64 {
            w.schedule(42.0, i, 0);
        }
        let order = drain(&mut w);
        assert_eq!(order.first(), Some(&(42.0, 0)));
        assert_eq!(order.last(), Some(&(42.0, 255)));
        assert!(order.windows(2).all(|p| p[0].1 < p[1].1));
    }
}
