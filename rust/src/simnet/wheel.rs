//! Calendar-queue event backend (DESIGN.md §13, §14).
//!
//! A classic Brown calendar queue: one "year" of fixed-width time
//! buckets, a virtual-bucket cursor (`epoch`) that sweeps forward, and
//! entries hashed into `bucket = vk % n_buckets` where
//! `vk = floor(time / width)`. Schedule is an O(1) push; pop scans the
//! cursor bucket for entries belonging to the current epoch and takes
//! the `(time, seq)` minimum, advancing the cursor over empty buckets.
//! With the width resized to track the mean inter-event gap the queue
//! holds ~one live event per bucket, making both operations O(1)
//! amortized — against O(log n) for the binary heap — which is what a
//! million-user campaign needs from its wake-up queue.
//!
//! **Two levels.** Entries more than one ring revolution past the
//! cursor are parked in an unordered *far bag* instead of the ring, and
//! promoted into the ring when the cursor approaches (an hour-hand /
//! minute-hand hierarchy with a degenerate hour hand: the bag). Without
//! it, a long event horizon over a narrow ring — exactly what the
//! bounded-lag windowed campaign produces, with wake-ups hundreds of
//! seconds out and windows tens of milliseconds wide — forces the ring
//! to grow to span the whole horizon and the width resample to thrash
//! between the near-gap and far-gap scales. With the bag, ring size and
//! bucket width track only the *near* population.
//!
//! Correctness invariant: **every ring-stored entry has `vk >= epoch`.**
//! Pop preserves it by construction (it only advances `epoch` past
//! buckets holding no current-epoch entry); schedule restores it by
//! rewinding `epoch` when a new entry lands earlier than the cursor
//! (legal: the cursor may have swept ahead of wall-clock `now` while
//! scanning toward a far-future event). Far entries satisfy the weaker
//! `vk >= insert-time horizon`; the pop loop promotes the bag's cohort
//! before the cursor can reach it, rewinding the cursor if a width
//! change left a promoted entry behind it. Bucket membership and epoch
//! eligibility use the *identical* float expression
//! `(t / width).floor()`, so an entry can never be hashed into a bucket
//! the eligibility test disagrees with.
//!
//! The wheel stores raw `(time, seq, payload)` triples; cancellation
//! bookkeeping (the pending/cancelled sets) stays in
//! [`super::des::Scheduler`], which lazily discards cancelled seqs as
//! they surface. Total order popped: ascending `(time, seq)` — the
//! exact tie-break contract of the heap backend, property-tested
//! against it in `simnet::des`.

/// Smallest bucket count; also the grow/shrink floor.
const MIN_BUCKETS: usize = 16;
/// Gap samples taken when re-picking the bucket width on resize.
const WIDTH_SAMPLES: usize = 64;

struct Entry<E> {
    time: f64,
    seq: u64,
    payload: E,
}

pub(crate) struct Wheel<E> {
    buckets: Vec<Vec<Entry<E>>>,
    /// bucket width in virtual seconds (> 0)
    width: f64,
    /// virtual bucket cursor: no ring-stored entry has `vk < epoch`
    epoch: u64,
    /// total entries, ring + far bag
    len: usize,
    /// entries beyond the ring horizon at insert time, unordered
    far: Vec<Entry<E>>,
    /// min `vk` over the far bag under the current width
    /// (`u64::MAX` when the bag is empty)
    far_min_vk: u64,
}

impl<E> Wheel<E> {
    pub(crate) fn new() -> Wheel<E> {
        Wheel {
            buckets: (0..MIN_BUCKETS).map(|_| Vec::new()).collect(),
            width: 1.0,
            epoch: 0,
            len: 0,
            far: Vec::new(),
            far_min_vk: u64::MAX,
        }
    }

    pub(crate) fn len(&self) -> usize {
        self.len
    }

    /// Ring population (total minus the far bag) — what the resize
    /// policy sizes the ring for.
    fn near_len(&self) -> usize {
        self.len - self.far.len()
    }

    /// Virtual bucket index of a timestamp. Times are clamped at zero:
    /// the scheduler's clock starts non-negative and never runs
    /// backwards, so negative times cannot reach us, but a clamp is
    /// cheaper than an unreachable panic path.
    #[inline]
    fn vk(&self, t: f64) -> u64 {
        (t.max(0.0) / self.width).floor() as u64
    }

    /// First vk past the ring's reach from the current cursor.
    #[inline]
    fn horizon(&self) -> u64 {
        self.epoch.saturating_add(self.buckets.len() as u64)
    }

    pub(crate) fn schedule(&mut self, time: f64, seq: u64, payload: E) {
        if self.vk(time) >= self.horizon() {
            // beyond the ring: O(1) park in the far bag; the pop loop
            // promotes the cohort when the cursor approaches
            self.far_min_vk = self.far_min_vk.min(self.vk(time));
            self.far.push(Entry { time, seq, payload });
        } else {
            self.insert_near(Entry { time, seq, payload });
        }
        self.len += 1;
    }

    /// Ring insert: grow if the ring is crowded, rewind the cursor if
    /// the entry lands behind it. Does not touch `len` (callers move
    /// entries between levels without changing the total).
    fn insert_near(&mut self, e: Entry<E>) {
        if self.near_len() >= self.buckets.len() * 2 {
            self.resize(self.buckets.len() * 2);
        }
        let vk = self.vk(e.time);
        if vk < self.epoch {
            self.epoch = vk;
        }
        let n = self.buckets.len() as u64;
        self.buckets[(vk % n) as usize].push(e);
    }

    /// Move every far entry inside the current ring horizon into the
    /// ring and recompute the bag minimum.
    fn promote_due_far(&mut self) {
        let horizon = self.horizon();
        let mut due = Vec::new();
        let mut i = 0;
        while i < self.far.len() {
            if self.vk(self.far[i].time) < horizon {
                due.push(self.far.swap_remove(i));
            } else {
                i += 1;
            }
        }
        for e in due {
            self.insert_near(e);
        }
        self.far_min_vk = self
            .far
            .iter()
            .map(|e| self.vk(e.time))
            .min()
            .unwrap_or(u64::MAX);
    }

    /// Remove and return the globally minimum `(time, seq)` entry.
    pub(crate) fn pop_min(&mut self) -> Option<(f64, u64, E)> {
        if self.len == 0 {
            return None;
        }
        let mut scanned = 0u64;
        loop {
            if !self.far.is_empty() {
                if self.near_len() == 0 && self.far_min_vk > self.epoch {
                    // empty ring: jump the cursor straight to the bag's
                    // first cohort instead of sweeping dead buckets
                    self.epoch = self.far_min_vk;
                }
                if self.far_min_vk < self.horizon() {
                    self.promote_due_far();
                    scanned = 0; // ring population changed; restart the dry count
                }
            }
            let n = self.buckets.len() as u64;
            let b = (self.epoch % n) as usize;
            let mut best: Option<usize> = None;
            for (i, e) in self.buckets[b].iter().enumerate() {
                if self.vk(e.time) != self.epoch {
                    continue; // a collision from a later revolution
                }
                best = Some(match best {
                    None => i,
                    Some(j) => {
                        let bj = &self.buckets[b][j];
                        if e.time.total_cmp(&bj.time).then(e.seq.cmp(&bj.seq)).is_lt() {
                            i
                        } else {
                            j
                        }
                    }
                });
            }
            if let Some(i) = best {
                return Some(self.take(b, i));
            }
            // empty virtual bucket: commit the cursor forward (this is
            // where the O(1) amortization comes from — each empty bucket
            // is crossed once, not re-scanned on every pop)
            self.epoch += 1;
            scanned += 1;
            if scanned >= n {
                // a full revolution without a hit: the next event is more
                // than a year ahead of the cursor. Jump straight to it.
                return Some(self.pop_global_min());
            }
        }
    }

    /// Fallback for sparse far-future schedules: linear scan of every
    /// ring bucket *and* the far bag for the global `(time, seq)`
    /// minimum, jumping the cursor to its epoch. O(n + len), amortized
    /// away by the resize policy. Safe cursor jump: `vk` is monotone in
    /// time, so the global-min time has the global-min vk and no stored
    /// entry ends up behind the cursor.
    fn pop_global_min(&mut self) -> (f64, u64, E) {
        debug_assert!(self.len > 0);
        let mut at: Option<(usize, usize)> = None;
        for (b, bucket) in self.buckets.iter().enumerate() {
            for (i, e) in bucket.iter().enumerate() {
                let better = match at {
                    None => true,
                    Some((pb, pi)) => {
                        let p = &self.buckets[pb][pi];
                        e.time.total_cmp(&p.time).then(e.seq.cmp(&p.seq)).is_lt()
                    }
                };
                if better {
                    at = Some((b, i));
                }
            }
        }
        let mut far_at: Option<usize> = None;
        for (j, e) in self.far.iter().enumerate() {
            let better = match far_at {
                None => true,
                Some(pj) => {
                    let p = &self.far[pj];
                    e.time.total_cmp(&p.time).then(e.seq.cmp(&p.seq)).is_lt()
                }
            };
            if better {
                far_at = Some(j);
            }
        }
        let far_wins = match (at, far_at) {
            (None, Some(_)) => true,
            (Some((pb, pi)), Some(pj)) => {
                let near = &self.buckets[pb][pi];
                let far = &self.far[pj];
                far.time
                    .total_cmp(&near.time)
                    .then(far.seq.cmp(&near.seq))
                    .is_lt()
            }
            _ => false,
        };
        if far_wins {
            let e = self.far.swap_remove(far_at.expect("far candidate"));
            self.len -= 1;
            self.epoch = self.vk(e.time);
            self.far_min_vk = self
                .far
                .iter()
                .map(|x| self.vk(x.time))
                .min()
                .unwrap_or(u64::MAX);
            return (e.time, e.seq, e.payload);
        }
        let (b, i) = at.expect("non-empty wheel has a minimum");
        self.epoch = self.vk(self.buckets[b][i].time);
        self.take(b, i)
    }

    fn take(&mut self, bucket: usize, i: usize) -> (f64, u64, E) {
        let e = self.buckets[bucket].swap_remove(i);
        self.len -= 1;
        if self.near_len() < self.buckets.len() / 4 && self.buckets.len() > MIN_BUCKETS {
            self.resize((self.buckets.len() / 2).max(MIN_BUCKETS));
        }
        (e.time, e.seq, e.payload)
    }

    /// Rebuild the ring with `n_new` buckets, re-picking the width from
    /// the mean gap of a sample of *ring* times so occupancy stays ~1
    /// per bucket. The far bag is untouched — its gaps are a different
    /// scale and must not pollute the width signal (the point of the
    /// two levels) — but its cached minimum is recomputed because vk
    /// values change with the width.
    fn resize(&mut self, n_new: usize) {
        let entries: Vec<Entry<E>> = self.buckets.iter_mut().flat_map(std::mem::take).collect();
        if let Some(w) = sample_width(&entries) {
            self.width = w;
        }
        self.buckets = (0..n_new).map(|_| Vec::new()).collect();
        // the cursor currently points at time ~ epoch * old_width; with a
        // new width the cheapest correct cursor is the minimum stored vk
        // (pop only requires that no ring entry precede the cursor; a far
        // entry that lands behind it is rewound over at promotion)
        self.epoch = entries
            .iter()
            .map(|e| self.vk(e.time))
            .min()
            .or_else(|| self.far.iter().map(|e| self.vk(e.time)).min())
            .unwrap_or(0);
        let n = n_new as u64;
        for e in entries {
            let vk = self.vk(e.time);
            self.buckets[(vk % n) as usize].push(e);
        }
        self.far_min_vk = self
            .far
            .iter()
            .map(|e| self.vk(e.time))
            .min()
            .unwrap_or(u64::MAX);
    }
}

/// Mean positive gap between up-to-[`WIDTH_SAMPLES`] sorted sampled
/// times, clamped to a sane range. `None` when the sample carries no
/// signal (fewer than two distinct times).
fn sample_width<E>(entries: &[Entry<E>]) -> Option<f64> {
    if entries.len() < 2 {
        return None;
    }
    let stride = (entries.len() / WIDTH_SAMPLES).max(1);
    let mut times: Vec<f64> = entries.iter().step_by(stride).map(|e| e.time).collect();
    times.sort_by(f64::total_cmp);
    let gaps: Vec<f64> = times.windows(2).map(|w| w[1] - w[0]).filter(|&g| g > 0.0).collect();
    if gaps.is_empty() {
        return None;
    }
    let mean = gaps.iter().sum::<f64>() / gaps.len() as f64;
    // classic calendar-queue practice: a bucket spans a few mean gaps
    Some((mean * 2.0).clamp(1e-6, 1e9))
}

#[cfg(test)]
mod tests {
    use super::*;

    fn drain(w: &mut Wheel<u32>) -> Vec<(f64, u64)> {
        let mut out = Vec::new();
        while let Some((t, s, _)) = w.pop_min() {
            out.push((t, s));
        }
        out
    }

    #[test]
    fn pops_in_time_then_seq_order() {
        let mut w = Wheel::new();
        w.schedule(3.0, 0, 0);
        w.schedule(1.0, 1, 0);
        w.schedule(1.0, 2, 0);
        w.schedule(0.5, 3, 0);
        assert_eq!(drain(&mut w), vec![(0.5, 3), (1.0, 1), (1.0, 2), (3.0, 0)]);
        assert_eq!(w.len(), 0);
    }

    #[test]
    fn far_future_event_found_via_global_fallback() {
        let mut w = Wheel::new();
        // more than a full revolution (16 buckets * 1 s) ahead: parked
        // in the far bag, found by the empty-ring cursor jump
        w.schedule(1e7, 0, 7);
        assert_eq!(w.far.len(), 1);
        assert_eq!(w.pop_min(), Some((1e7, 0, 7)));
    }

    #[test]
    fn schedule_behind_swept_cursor_is_still_found() {
        let mut w = Wheel::new();
        // sweep the cursor far forward by popping a far-future event
        w.schedule(1000.0, 0, 0);
        assert!(w.pop_min().is_some());
        // a later schedule into an earlier virtual bucket (legal: the
        // >= now guard is the Scheduler's business, and `peek_time` can
        // sweep the cursor past `now`) must rewind the cursor so the
        // entry stays visible
        w.schedule(500.0, 1, 1);
        w.schedule(1000.5, 2, 2);
        assert_eq!(w.epoch, 500);
        assert_eq!(drain(&mut w), vec![(500.0, 1), (1000.5, 2)]);
    }

    #[test]
    fn grows_and_shrinks_through_resize() {
        let mut w = Wheel::new();
        for i in 0..4096u64 {
            w.schedule(i as f64 * 0.125, i, i as u32);
        }
        assert!(w.buckets.len() > MIN_BUCKETS);
        let order = drain(&mut w);
        assert_eq!(order.len(), 4096);
        assert!(order.windows(2).all(|p| p[0] <= p[1]), "out of order");
        assert_eq!(w.buckets.len(), MIN_BUCKETS);
    }

    #[test]
    fn identical_times_resize_without_width_signal() {
        // all-equal times give sample_width nothing; the resize must
        // keep the old width and stay correct
        let mut w = Wheel::new();
        for i in 0..256u64 {
            w.schedule(42.0, i, 0);
        }
        let order = drain(&mut w);
        assert_eq!(order.first(), Some(&(42.0, 0)));
        assert_eq!(order.last(), Some(&(42.0, 255)));
        assert!(order.windows(2).all(|p| p[0].1 < p[1].1));
    }

    #[test]
    fn far_horizon_population_stays_out_of_the_ring() {
        // the bounded-lag shape: a handful of near wake-ups, thousands
        // of events hundreds of seconds out. The ring must not grow to
        // span the horizon — the far population parks in the bag.
        let mut w = Wheel::new();
        for i in 0..8u64 {
            w.schedule(i as f64 * 0.5, i, 0);
        }
        for i in 0..10_000u64 {
            w.schedule(900.0 + i as f64 * 0.01, 8 + i, 1);
        }
        assert_eq!(w.len(), 10_008);
        assert_eq!(
            w.buckets.len(),
            MIN_BUCKETS,
            "far events must not force ring growth"
        );
        assert!(w.far.len() >= 10_000);
        // near events pop first and in order, never seeing the far mass
        for i in 0..8u64 {
            let (t, s, _) = w.pop_min().unwrap();
            assert_eq!((t, s), (i as f64 * 0.5, i));
        }
        // then the promoted far cohorts, still in (time, seq) order
        let order = drain(&mut w);
        assert_eq!(order.len(), 10_000);
        assert!(order.windows(2).all(|p| p[0] < p[1]), "out of order");
    }

    #[test]
    fn promotion_interleaves_with_fresh_near_schedules() {
        // far entries promoted into the ring must merge correctly with
        // entries scheduled near after the cursor has swept forward
        let mut w = Wheel::new();
        w.schedule(100.0, 0, 0); // far at insert (horizon = 16)
        w.schedule(1.0, 1, 1); // near
        assert_eq!(w.pop_min(), Some((1.0, 1, 1)));
        // cursor still near 1.0; schedule between it and the far entry
        w.schedule(50.0, 2, 2);
        w.schedule(100.0, 3, 3); // same instant as the far entry, later seq
        assert_eq!(w.pop_min(), Some((50.0, 2, 2)));
        assert_eq!(w.pop_min(), Some((100.0, 0, 0)));
        assert_eq!(w.pop_min(), Some((100.0, 3, 3)));
        assert_eq!(w.pop_min(), None);
    }
}
