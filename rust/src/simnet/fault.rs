//! Fault injection for the WAN and FaaS fabrics.
//!
//! The transfer service (paper §3: Globus "provides fault recovery")
//! needs failures to recover *from*. Two layers live here:
//!
//! * [`FaultModel`] — stochastic per-file transfer failures,
//!   deterministically seeded so every experiment is reproducible;
//! * [`FaultPlan`] — *scheduled* campaign-level faults over virtual-time
//!   windows (DESIGN.md §9): [`EndpointOutage`]s take a faas endpoint
//!   `Down` (running tasks failed-with-retry, queue survives) and
//!   [`WanDegradation`]s scale every WAN link's capacity by a factor
//!   while active (transfers are re-water-filled at the transition).
//!   The campaign driver turns each window edge into a `simnet::des`
//!   event.

use anyhow::{bail, Result};

use crate::util::Rng;

/// Failure model parameters.
#[derive(Debug, Clone)]
pub struct FaultModel {
    /// probability a single file transfer attempt fails mid-flight
    pub file_failure_prob: f64,
    /// virtual seconds a failed file waits before its next attempt
    /// starts (a fixed pause, not exponential — Globus-style polling)
    pub retry_backoff_s: f64,
    /// maximum attempts per file before the whole transfer fails hard
    /// (so `max_attempts - 1` retries after the first try)
    pub max_attempts: u32,
}

impl FaultModel {
    /// No faults (the default for paper-table reproduction).
    pub fn none() -> FaultModel {
        FaultModel {
            file_failure_prob: 0.0,
            retry_backoff_s: 5.0,
            max_attempts: 3,
        }
    }

    /// A lossy WAN for failure-injection tests.
    pub fn flaky(p: f64) -> FaultModel {
        FaultModel {
            file_failure_prob: p,
            retry_backoff_s: 5.0,
            max_attempts: 5,
        }
    }

    /// Draw the attempt outcome for one file: `None` = success, or
    /// `Some(fraction_completed_before_failure)` — the fraction of the
    /// file already moved when the attempt died, uniform in [0, 1).
    /// Those bytes are wasted and must be re-sent (the wire does not
    /// refund retries), which is what makes flaky WANs expensive.
    pub fn draw_failure(&self, rng: &mut Rng) -> Option<f64> {
        if self.file_failure_prob > 0.0 && rng.chance(self.file_failure_prob) {
            Some(rng.f64())
        } else {
            None
        }
    }
}

/// One faas endpoint taken `Down` over `[from_vt, until_vt)`.
#[derive(Debug, Clone, PartialEq)]
pub struct EndpointOutage {
    pub endpoint: String,
    pub from_vt: f64,
    pub until_vt: f64,
}

/// Every WAN link's capacity scaled by `factor` over `[from_vt,
/// until_vt)` — a backbone brownout. Overlapping degradations compose
/// by taking the most severe (smallest) active factor.
#[derive(Debug, Clone, PartialEq)]
pub struct WanDegradation {
    /// capacity multiplier in (0, 1]
    pub factor: f64,
    pub from_vt: f64,
    pub until_vt: f64,
}

/// A whole federated site taken `Down` over `[from_vt, until_vt)`:
/// every faas endpoint at the site goes dark at once and the placement
/// broker must reroute (DESIGN.md §15). Only meaningful when the
/// campaign runs with `--sites`; the site name is validated against the
/// active site set by the campaign driver, not here.
#[derive(Debug, Clone, PartialEq)]
pub struct SiteOutage {
    pub site: String,
    pub from_vt: f64,
    pub until_vt: f64,
}

/// Scheduled campaign-level faults (DESIGN.md §9).
#[derive(Debug, Clone, Default, PartialEq)]
pub struct FaultPlan {
    pub outages: Vec<EndpointOutage>,
    pub wan: Vec<WanDegradation>,
    pub sites: Vec<SiteOutage>,
}

impl FaultPlan {
    pub fn is_empty(&self) -> bool {
        self.outages.is_empty() && self.wan.is_empty() && self.sites.is_empty()
    }

    /// Parse a comma-separated spec, e.g.
    /// `outage=alcf#cerebras@500..2000,wan=0.25@100..1500`.
    pub fn parse(spec: &str) -> Result<FaultPlan> {
        let mut plan = FaultPlan::default();
        for entry in spec.split(',') {
            let entry = entry.trim();
            if entry.is_empty() {
                continue;
            }
            let (kind, rest) = entry
                .split_once('=')
                .ok_or_else(|| anyhow::anyhow!("bad fault entry `{entry}` (want kind=...)"))?;
            let (subject, window) = rest
                .split_once('@')
                .ok_or_else(|| anyhow::anyhow!("bad fault entry `{entry}` (want ...@from..until)"))?;
            let (from_s, until_s) = window.split_once("..").ok_or_else(|| {
                anyhow::anyhow!("bad fault window `{window}` (want from..until)")
            })?;
            let from_vt: f64 = from_s
                .parse()
                .map_err(|_| anyhow::anyhow!("bad fault window start `{from_s}`"))?;
            let until_vt: f64 = until_s
                .parse()
                .map_err(|_| anyhow::anyhow!("bad fault window end `{until_s}`"))?;
            match kind.trim() {
                "outage" => plan.outages.push(EndpointOutage {
                    endpoint: subject.trim().to_string(),
                    from_vt,
                    until_vt,
                }),
                "wan" => {
                    let factor: f64 = subject
                        .trim()
                        .parse()
                        .map_err(|_| anyhow::anyhow!("bad wan factor `{subject}`"))?;
                    plan.wan.push(WanDegradation {
                        factor,
                        from_vt,
                        until_vt,
                    });
                }
                "site" => plan.sites.push(SiteOutage {
                    site: subject.trim().to_string(),
                    from_vt,
                    until_vt,
                }),
                other => bail!("unknown fault kind `{other}` (outage, wan, site)"),
            }
        }
        plan.validate()?;
        Ok(plan)
    }

    /// Windows must be finite, non-empty, non-negative; wan factors in
    /// (0, 1]; outage windows on the same endpoint must not overlap
    /// (the begin/end transitions would cancel each other).
    pub fn validate(&self) -> Result<()> {
        for o in &self.outages {
            if !(o.from_vt.is_finite() && o.until_vt.is_finite())
                || o.from_vt < 0.0
                || o.until_vt <= o.from_vt
            {
                bail!(
                    "bad outage window [{}, {}) for `{}`",
                    o.from_vt,
                    o.until_vt,
                    o.endpoint
                );
            }
        }
        for (i, a) in self.outages.iter().enumerate() {
            for b in self.outages.iter().skip(i + 1) {
                if a.endpoint == b.endpoint
                    && a.from_vt < b.until_vt
                    && b.from_vt < a.until_vt
                {
                    bail!("overlapping outage windows on `{}`", a.endpoint);
                }
            }
        }
        for w in &self.wan {
            if !(w.from_vt.is_finite() && w.until_vt.is_finite())
                || w.from_vt < 0.0
                || w.until_vt <= w.from_vt
            {
                bail!("bad wan window [{}, {})", w.from_vt, w.until_vt);
            }
            if !(w.factor > 0.0 && w.factor <= 1.0) {
                bail!("wan factor must be in (0, 1], got {}", w.factor);
            }
        }
        for s in &self.sites {
            if s.site.is_empty() {
                bail!("site outage with empty site name");
            }
            if !(s.from_vt.is_finite() && s.until_vt.is_finite())
                || s.from_vt < 0.0
                || s.until_vt <= s.from_vt
            {
                bail!(
                    "bad site outage window [{}, {}) for `{}`",
                    s.from_vt,
                    s.until_vt,
                    s.site
                );
            }
        }
        for (i, a) in self.sites.iter().enumerate() {
            for b in self.sites.iter().skip(i + 1) {
                if a.site == b.site && a.from_vt < b.until_vt && b.from_vt < a.until_vt {
                    bail!("overlapping site outage windows on `{}`", a.site);
                }
            }
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn none_never_fails() {
        let m = FaultModel::none();
        let mut rng = Rng::new(1);
        for _ in 0..1000 {
            assert!(m.draw_failure(&mut rng).is_none());
        }
    }

    #[test]
    fn flaky_fails_at_expected_rate() {
        let m = FaultModel::flaky(0.3);
        let mut rng = Rng::new(2);
        let fails = (0..10_000)
            .filter(|_| m.draw_failure(&mut rng).is_some())
            .count();
        assert!((fails as f64 / 10_000.0 - 0.3).abs() < 0.02, "{fails}");
    }

    #[test]
    fn failure_fraction_in_unit_interval() {
        let m = FaultModel::flaky(1.0);
        let mut rng = Rng::new(3);
        for _ in 0..100 {
            let f = m.draw_failure(&mut rng).unwrap();
            assert!((0.0..1.0).contains(&f));
        }
    }

    #[test]
    fn fault_plan_parses_mixed_spec() {
        let p = FaultPlan::parse("outage=alcf#cerebras@500..2000, wan=0.25@100..1500").unwrap();
        assert_eq!(
            p.outages,
            vec![EndpointOutage {
                endpoint: "alcf#cerebras".into(),
                from_vt: 500.0,
                until_vt: 2000.0,
            }]
        );
        assert_eq!(
            p.wan,
            vec![WanDegradation {
                factor: 0.25,
                from_vt: 100.0,
                until_vt: 1500.0,
            }]
        );
        assert!(!p.is_empty());
        assert!(FaultPlan::parse("").unwrap().is_empty());
    }

    #[test]
    fn fault_plan_rejects_bad_specs() {
        assert!(FaultPlan::parse("outage=e@5..2").is_err()); // empty window
        assert!(FaultPlan::parse("wan=1.5@0..10").is_err()); // factor > 1
        assert!(FaultPlan::parse("wan=0@0..10").is_err()); // factor 0
        assert!(FaultPlan::parse("brownout=x@0..1").is_err()); // kind
        assert!(FaultPlan::parse("outage=e@nope..1").is_err());
        assert!(FaultPlan::parse("outage=e@0..1,outage=e@0.5..2").is_err()); // overlap
        // same endpoint, disjoint windows: fine
        assert!(FaultPlan::parse("outage=e@0..1,outage=e@2..3").is_ok());
    }

    /// `validate` edge cases that `parse` can also hand it (and that
    /// programmatic plans hit directly): degenerate windows, reversed
    /// bounds, negative starts, non-finite edges, and the exact
    /// boundaries of the same-endpoint overlap rule.
    #[test]
    fn fault_plan_validate_edge_cases() {
        let outage = |endpoint: &str, from_vt: f64, until_vt: f64| FaultPlan {
            outages: vec![EndpointOutage {
                endpoint: endpoint.into(),
                from_vt,
                until_vt,
            }],
            ..FaultPlan::default()
        };
        // zero-length window: [5, 5) injects nothing — rejected
        assert!(outage("e", 5.0, 5.0).validate().is_err());
        assert!(FaultPlan::parse("outage=e@5..5").is_err());
        // reversed bounds and negative start
        assert!(outage("e", 10.0, 2.0).validate().is_err());
        assert!(outage("e", -1.0, 2.0).validate().is_err());
        // non-finite edges (unreachable via parse — `inf` parses as f64
        // — so validate is the only guard)
        assert!(outage("e", f64::NAN, 2.0).validate().is_err());
        assert!(outage("e", 0.0, f64::INFINITY).validate().is_err());
        // back-to-back windows on one endpoint share an instant without
        // overlapping: the end transition at t=1 precedes the begin
        assert!(FaultPlan::parse("outage=e@0..1,outage=e@1..2").is_ok());
        // identical windows on *different* endpoints never conflict
        assert!(FaultPlan::parse("outage=a@0..5,outage=b@0..5").is_ok());
        // duplicate-endpoint identical windows are the overlap case
        assert!(FaultPlan::parse("outage=e@0..5,outage=e@0..5")
            .unwrap_err()
            .to_string()
            .contains("overlapping"));
        // wan windows get the same window checks plus the factor range
        let wan = |factor: f64, from_vt: f64, until_vt: f64| FaultPlan {
            wan: vec![WanDegradation {
                factor,
                from_vt,
                until_vt,
            }],
            ..FaultPlan::default()
        };
        assert!(wan(0.5, 3.0, 3.0).validate().is_err());
        assert!(wan(f64::NAN, 0.0, 1.0).validate().is_err());
        assert!(wan(1.0, 0.0, 1.0).validate().is_ok()); // factor 1.0 inclusive
        // overlapping wan windows are allowed — they compose by
        // most-severe-factor, unlike outages
        assert!(FaultPlan::parse("wan=0.5@0..10,wan=0.25@5..15").is_ok());
    }

    #[test]
    fn site_outage_windows_parse_and_validate() {
        let p = FaultPlan::parse("site=nersc@100..900").unwrap();
        assert_eq!(
            p.sites,
            vec![SiteOutage {
                site: "nersc".into(),
                from_vt: 100.0,
                until_vt: 900.0,
            }]
        );
        assert!(!p.is_empty());
        // site windows get the same window checks as endpoint outages
        assert!(FaultPlan::parse("site=nersc@5..5").is_err());
        assert!(FaultPlan::parse("site=nersc@9..2").is_err());
        assert!(FaultPlan::parse("site=@0..10").is_err()); // empty name
        // same site overlapping: rejected; disjoint and distinct-site: fine
        assert!(FaultPlan::parse("site=nersc@0..5,site=nersc@3..9")
            .unwrap_err()
            .to_string()
            .contains("overlapping"));
        assert!(FaultPlan::parse("site=nersc@0..5,site=nersc@5..9").is_ok());
        assert!(FaultPlan::parse("site=nersc@0..5,site=ornl@0..5").is_ok());
        // composes with the other kinds in one spec
        let mixed = FaultPlan::parse("outage=alcf#gpu8@0..9,site=nersc@4..8,wan=0.5@1..2").unwrap();
        assert_eq!(mixed.outages.len(), 1);
        assert_eq!(mixed.sites.len(), 1);
        assert_eq!(mixed.wan.len(), 1);
    }
}
