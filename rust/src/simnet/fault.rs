//! Fault injection for the WAN fabric.
//!
//! The transfer service (paper §3: Globus "provides fault recovery")
//! needs failures to recover *from*. This model injects per-file transfer
//! failures and endpoint outages, deterministically seeded so every
//! experiment is reproducible.

use crate::util::Rng;

/// Failure model parameters.
#[derive(Debug, Clone)]
pub struct FaultModel {
    /// probability a single file transfer attempt fails mid-flight
    pub file_failure_prob: f64,
    /// when a failure happens, the fraction of the file already moved is
    /// uniform in [0, 1) — wasted bytes that must be re-sent
    pub retry_backoff_s: f64,
    /// maximum attempts per file before the task fails hard
    pub max_attempts: u32,
}

impl FaultModel {
    /// No faults (the default for paper-table reproduction).
    pub fn none() -> FaultModel {
        FaultModel {
            file_failure_prob: 0.0,
            retry_backoff_s: 5.0,
            max_attempts: 3,
        }
    }

    /// A lossy WAN for failure-injection tests.
    pub fn flaky(p: f64) -> FaultModel {
        FaultModel {
            file_failure_prob: p,
            retry_backoff_s: 5.0,
            max_attempts: 5,
        }
    }

    /// Draw the attempt outcome for one file: `None` = success, or
    /// `Some(fraction_completed_before_failure)`.
    pub fn draw_failure(&self, rng: &mut Rng) -> Option<f64> {
        if self.file_failure_prob > 0.0 && rng.chance(self.file_failure_prob) {
            Some(rng.f64())
        } else {
            None
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn none_never_fails() {
        let m = FaultModel::none();
        let mut rng = Rng::new(1);
        for _ in 0..1000 {
            assert!(m.draw_failure(&mut rng).is_none());
        }
    }

    #[test]
    fn flaky_fails_at_expected_rate() {
        let m = FaultModel::flaky(0.3);
        let mut rng = Rng::new(2);
        let fails = (0..10_000)
            .filter(|_| m.draw_failure(&mut rng).is_some())
            .count();
        assert!((fails as f64 / 10_000.0 - 0.3).abs() < 0.02, "{fails}");
    }

    #[test]
    fn failure_fraction_in_unit_interval() {
        let m = FaultModel::flaky(1.0);
        let mut rng = Rng::new(3);
        for _ in 0..100 {
            let f = m.draw_failure(&mut rng).unwrap();
            assert!((0.0..1.0).contains(&f));
        }
    }
}
