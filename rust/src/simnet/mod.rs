//! Discrete-event WAN simulator: virtual clock, event-queue scheduler,
//! facility/link topology, max-min fair fluid bandwidth sharing, and
//! fault injection.
//!
//! Substitutes for the physical ESnet SLAC<->ALCF path of the paper
//! (DESIGN.md §2) while preserving the behaviours the evaluation depends
//! on: NIC/backbone bottlenecks, RTT-dominated startup, concurrency
//! scaling (Fig. 3), and transfer fault recovery.

pub mod clock;
pub mod des;
pub mod fault;
pub mod fluid;
pub mod topology;
pub mod wheel;

pub use clock::{VClock, VSpan};
pub use des::{DesBackend, EventId, Scheduler, WHEEL_THRESHOLD};
pub use fault::{EndpointOutage, FaultModel, FaultPlan, SiteOutage, WanDegradation};
pub use fluid::{max_min_rates, simulate, FlowResult, FlowSpec};
pub use topology::{Facility, FacilityId, Link, LinkId, Topology, GBPS};
