//! Max-min fair fluid flow simulation.
//!
//! Concurrent transfers share links; the throughput each one sees is the
//! max-min fair ("water-filling") allocation over every link it crosses.
//! This is the standard fluid model for TCP-fair bulk transfers on
//! over-provisioned R&E networks (paper §4.1: ESnet/Internet2 keep
//! backbone utilization under ~40%, so fair-share, not congestion
//! collapse, is the operative regime).
//!
//! The simulation is event-driven and exact for piecewise-constant rate
//! sets: rates change only at flow arrival/completion instants, so we
//! re-solve the allocation at each event and jump to the next one.
//! Complexity O(F * L * F) per event, microscopic at our scales.

use std::collections::BTreeMap;

use super::topology::{LinkId, Topology};

/// A bulk data flow to simulate.
#[derive(Debug, Clone)]
pub struct FlowSpec {
    pub route: Vec<LinkId>,
    pub bytes: f64,
    /// absolute virtual time the flow becomes active
    pub arrival: f64,
}

/// Completion record for one flow.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct FlowResult {
    pub start: f64,
    pub finish: f64,
}

impl FlowResult {
    pub fn duration(&self) -> f64 {
        self.finish - self.start
    }
}

/// Max-min fair rate allocation for the given active flows.
///
/// Returns one rate (bytes/s) per flow. Pure function — exposed for
/// property tests (rates must saturate at least one link unless all flows
/// are bottlenecked elsewhere, never exceed any link capacity, etc.).
pub fn max_min_rates(topo: &Topology, routes: &[&[LinkId]]) -> Vec<f64> {
    let n = routes.len();
    let mut rates = vec![0.0; n];
    if n == 0 {
        return rates;
    }
    let mut remaining_cap: BTreeMap<LinkId, f64> = BTreeMap::new();
    for r in routes {
        for &l in *r {
            remaining_cap
                .entry(l)
                .or_insert_with(|| topo.link(l).capacity_bps);
        }
    }
    let mut unfixed: Vec<usize> = (0..n).collect();
    while !unfixed.is_empty() {
        // per-link fair share among unfixed flows crossing it
        let mut best: Option<(LinkId, f64)> = None;
        for (&l, &cap) in &remaining_cap {
            let users = unfixed
                .iter()
                .filter(|&&f| routes[f].contains(&l))
                .count();
            if users == 0 {
                continue;
            }
            let share = cap / users as f64;
            if best.map(|(_, s)| share < s).unwrap_or(true) {
                best = Some((l, share));
            }
        }
        let Some((bottleneck, share)) = best else {
            // remaining flows cross no capacitated link: unconstrained
            // (cannot happen with non-empty routes); give them zero.
            break;
        };
        // fix every unfixed flow crossing the bottleneck
        let (fixed, rest): (Vec<usize>, Vec<usize>) = unfixed
            .into_iter()
            .partition(|&f| routes[f].contains(&bottleneck));
        for &f in &fixed {
            rates[f] = share;
            for &l in routes[f] {
                if let Some(cap) = remaining_cap.get_mut(&l) {
                    *cap = (*cap - share).max(0.0);
                }
            }
        }
        remaining_cap.remove(&bottleneck);
        unfixed = rest;
    }
    rates
}

/// Simulate a set of flows to completion; returns per-flow results in
/// input order.
pub fn simulate(topo: &Topology, flows: &[FlowSpec]) -> Vec<FlowResult> {
    #[derive(Debug)]
    struct Active {
        idx: usize,
        remaining: f64,
    }

    let mut results: Vec<FlowResult> = flows
        .iter()
        .map(|f| FlowResult {
            start: f.arrival,
            finish: f64::NAN,
        })
        .collect();

    // arrival order
    let mut pending: Vec<usize> = (0..flows.len()).collect();
    pending.sort_by(|&a, &b| flows[a].arrival.total_cmp(&flows[b].arrival));
    let mut pending = std::collections::VecDeque::from(pending);

    let mut active: Vec<Active> = Vec::new();
    let mut t = 0.0f64;

    loop {
        // admit arrivals at or before t
        while pending
            .front()
            .map(|&i| flows[i].arrival <= t + 1e-12)
            .unwrap_or(false)
        {
            let i = pending.pop_front().unwrap();
            if flows[i].bytes <= 0.0 {
                results[i].finish = flows[i].arrival;
            } else {
                active.push(Active {
                    idx: i,
                    remaining: flows[i].bytes,
                });
            }
        }

        if active.is_empty() {
            match pending.front() {
                Some(&i) => {
                    t = flows[i].arrival;
                    continue;
                }
                None => break,
            }
        }

        let routes: Vec<&[LinkId]> = active
            .iter()
            .map(|a| flows[a.idx].route.as_slice())
            .collect();
        let rates = max_min_rates(topo, &routes);

        // next event: earliest completion or next arrival
        let mut dt = f64::INFINITY;
        for (a, &r) in active.iter().zip(&rates) {
            if r > 0.0 {
                dt = dt.min(a.remaining / r);
            }
        }
        if let Some(&i) = pending.front() {
            dt = dt.min(flows[i].arrival - t);
        }
        assert!(
            dt.is_finite(),
            "stalled fluid simulation (zero-rate flows and no arrivals)"
        );

        // advance
        t += dt;
        for (a, &r) in active.iter_mut().zip(&rates) {
            a.remaining -= r * dt;
        }
        active.retain(|a| {
            // one byte of slack so float rounding at large t cannot stall
            if a.remaining <= 1.0 {
                results[a.idx].finish = t;
                false
            } else {
                true
            }
        });
    }

    results
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::simnet::topology::GBPS;

    fn topo() -> Topology {
        Topology::paper()
    }

    fn slac_alcf_route(t: &Topology) -> Vec<LinkId> {
        let slac = t.facility("slac").unwrap();
        let alcf = t.facility("alcf").unwrap();
        t.route(slac, alcf).unwrap().to_vec()
    }

    #[test]
    fn single_flow_gets_bottleneck_bandwidth() {
        let t = topo();
        let route = slac_alcf_route(&t);
        let gb = 1e9;
        let res = simulate(
            &t,
            &[FlowSpec {
                route,
                bytes: 10.0 * gb,
                arrival: 0.0,
            }],
        );
        // bottleneck = 10 Gbps NIC = 1.25 GB/s -> 8 s
        assert!((res[0].duration() - 8.0).abs() < 1e-6, "{res:?}");
    }

    #[test]
    fn concurrent_flows_share_fairly() {
        let t = topo();
        let route = slac_alcf_route(&t);
        let gb = 1e9;
        let flows: Vec<FlowSpec> = (0..4)
            .map(|_| FlowSpec {
                route: route.clone(),
                bytes: 1.0 * gb,
                arrival: 0.0,
            })
            .collect();
        let res = simulate(&t, &flows);
        // 4 equal flows over a 1.25 GB/s bottleneck: all finish at 3.2 s
        for r in &res {
            assert!((r.finish - 3.2).abs() < 1e-6, "{res:?}");
        }
    }

    #[test]
    fn later_arrival_slows_first_flow() {
        let t = topo();
        let route = slac_alcf_route(&t);
        let gb = 1e9;
        let res = simulate(
            &t,
            &[
                FlowSpec {
                    route: route.clone(),
                    bytes: 2.5 * gb,
                    arrival: 0.0,
                },
                FlowSpec {
                    route,
                    bytes: 1.25 * gb,
                    arrival: 1.0,
                },
            ],
        );
        // flow0 alone for 1 s (1.25 GB done), then shares 0.625 GB/s each.
        // flow0: 1.25 GB left / 0.625 = 2 s more -> finishes t=3
        // flow1: 1.25 GB at 0.625 GB/s = 2 s -> finishes t=3
        assert!((res[0].finish - 3.0).abs() < 1e-6, "{res:?}");
        assert!((res[1].finish - 3.0).abs() < 1e-6, "{res:?}");
    }

    #[test]
    fn narrow_backbone_binds_before_nics() {
        let j = crate::util::Json::parse(
            r#"{
            "facilities": ["a", "b"],
            "links": [
                {"name": "nic-a", "gbps": 10.0, "latency_ms": 0.5},
                {"name": "bb", "gbps": 8.0, "latency_ms": 20.0},
                {"name": "nic-b", "gbps": 10.0, "latency_ms": 0.5}
            ],
            "routes": [{"from": "a", "to": "b", "links": ["nic-a", "bb", "nic-b"]}]
        }"#,
        )
        .unwrap();
        let t = Topology::from_json(&j).unwrap();
        let a = t.facility("a").unwrap();
        let b = t.facility("b").unwrap();
        let route = t.route(a, b).unwrap().to_vec();
        // 2 flows: 8 Gbps backbone shares at 4 each (< NIC share of 5)
        let rates = max_min_rates(&t, &[&route, &route]);
        assert!((rates[0] - 4.0 * GBPS).abs() < 1.0, "{rates:?}");
        assert!((rates[1] - 4.0 * GBPS).abs() < 1.0, "{rates:?}");
        // 1 flow: backbone still binds (8 < 10)
        let rates = max_min_rates(&t, &[&route]);
        assert!((rates[0] - 8.0 * GBPS).abs() < 1.0, "{rates:?}");
    }

    #[test]
    fn rates_never_exceed_any_link() {
        let t = topo();
        let route = slac_alcf_route(&t);
        for n in 1..20 {
            let routes: Vec<&[LinkId]> = (0..n).map(|_| route.as_slice()).collect();
            let rates = max_min_rates(&t, &routes);
            let total: f64 = rates.iter().sum();
            assert!(total <= 10.0 * GBPS + 1e-3, "n={n} total={total}");
            // work-conserving: bottleneck saturated
            assert!(total >= 10.0 * GBPS - 1e-3, "n={n} total={total}");
        }
    }

    #[test]
    fn zero_byte_flow_among_active_flows() {
        // a zero-byte flow arriving mid-transfer completes instantly at
        // its arrival and must not perturb the bulk flow sharing its route
        let t = topo();
        let route = slac_alcf_route(&t);
        let gb = 1e9;
        let res = simulate(
            &t,
            &[
                FlowSpec {
                    route: route.clone(),
                    bytes: 10.0 * gb,
                    arrival: 0.0,
                },
                FlowSpec {
                    route,
                    bytes: 0.0,
                    arrival: 1.0,
                },
            ],
        );
        assert_eq!(res[1].finish, 1.0);
        assert_eq!(res[1].duration(), 0.0);
        // bulk flow keeps the full 1.25 GB/s bottleneck: 8 s exactly
        assert!((res[0].finish - 8.0).abs() < 1e-6, "{res:?}");
    }

    #[test]
    fn simultaneous_arrivals_split_exactly() {
        // three flows arriving at the same nonzero instant must all be
        // admitted together and share the bottleneck three ways exactly
        let t = topo();
        let route = slac_alcf_route(&t);
        let gb = 1e9;
        let flows: Vec<FlowSpec> = (0..3)
            .map(|_| FlowSpec {
                route: route.clone(),
                bytes: 1.25 * gb,
                arrival: 5.0,
            })
            .collect();
        let res = simulate(&t, &flows);
        // 1.25 GB each at (1.25 GB/s) / 3: duration 3 s, finish t = 8 s
        for r in &res {
            assert_eq!(r.start, 5.0);
            assert!((r.finish - 8.0).abs() < 1e-9, "{res:?}");
        }
        // identical flows must finish at the identical instant, bit-exact
        assert_eq!(res[0].finish, res[1].finish);
        assert_eq!(res[1].finish, res[2].finish);
    }

    #[test]
    fn full_route_overlap_split_is_exact() {
        // a flow whose route shares EVERY link with another: the max-min
        // split of the bottleneck must be exact — equal rates, bit-exact,
        // summing to the bottleneck capacity
        let t = topo();
        let route = slac_alcf_route(&t);
        let rates = max_min_rates(&t, &[&route, &route]);
        assert_eq!(rates[0], rates[1], "{rates:?}");
        let bottleneck = 10.0 * GBPS; // the 10 Gbps NIC
        assert!((rates[0] + rates[1] - bottleneck).abs() < 1e-6, "{rates:?}");
        assert!((rates[0] - 0.5 * bottleneck).abs() < 1e-6, "{rates:?}");
    }

    #[test]
    fn zero_byte_flow_finishes_at_arrival() {
        let t = topo();
        let route = slac_alcf_route(&t);
        let res = simulate(
            &t,
            &[FlowSpec {
                route,
                bytes: 0.0,
                arrival: 2.0,
            }],
        );
        assert_eq!(res[0].finish, 2.0);
    }
}
