//! Virtual clock for the discrete-event fabric.
//!
//! Everything the paper measured on infrastructure we don't have (ESnet,
//! DCAI machines) is accounted in *virtual seconds* on this clock; real
//! wallclock (PJRT executions) is measured separately by `metrics`.
//! DESIGN.md §7 defines the two-clock discipline.

/// Monotonic virtual clock (seconds).
#[derive(Debug, Clone, Default)]
pub struct VClock {
    now: f64,
}

impl VClock {
    pub fn new() -> VClock {
        VClock { now: 0.0 }
    }

    /// A clock already at `t` — scratch clocks measuring the duration of
    /// work that begins mid-simulation (faas bodies under the DES
    /// scheduler) start here.
    pub fn starting_at(t: f64) -> VClock {
        assert!(t >= 0.0 && t.is_finite(), "bad clock origin {t}");
        VClock { now: t }
    }

    pub fn now(&self) -> f64 {
        self.now
    }

    /// Advance by a non-negative duration.
    pub fn advance(&mut self, dt: f64) {
        assert!(dt >= 0.0 && dt.is_finite(), "bad clock delta {dt}");
        self.now += dt;
    }

    /// Jump to an absolute time not before the present.
    ///
    /// The backwards tolerance is *relative* to the current time: two
    /// float paths to the same instant diverge in the last bits, and the
    /// absolute error of that divergence grows with the magnitude of the
    /// virtual time. A fixed absolute tolerance (the old `1e-9`) starts
    /// rejecting legitimate same-instant jumps once campaigns run for
    /// ~1e6 virtual seconds; a relative one stays calibrated at every
    /// scale.
    pub fn advance_to(&mut self, t: f64) {
        let tol = 1e-9 * self.now.abs().max(1.0);
        assert!(
            t >= self.now - tol,
            "clock would move backwards: {} -> {t}",
            self.now
        );
        self.now = self.now.max(t);
    }
}

/// A span of virtual time, for per-phase breakdowns.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct VSpan {
    pub start: f64,
    pub end: f64,
}

impl VSpan {
    pub fn duration(&self) -> f64 {
        self.end - self.start
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn advances() {
        let mut c = VClock::new();
        c.advance(1.5);
        c.advance(0.5);
        assert_eq!(c.now(), 2.0);
        c.advance_to(5.0);
        assert_eq!(c.now(), 5.0);
        c.advance_to(5.0); // no-op is fine
        assert_eq!(c.now(), 5.0);
    }

    #[test]
    #[should_panic]
    fn rejects_negative_delta() {
        VClock::new().advance(-1.0);
    }

    #[test]
    #[should_panic]
    fn rejects_backwards_jump() {
        let mut c = VClock::new();
        c.advance(10.0);
        c.advance_to(1.0);
    }

    #[test]
    fn starts_at_arbitrary_origin() {
        let mut c = VClock::starting_at(123.5);
        assert_eq!(c.now(), 123.5);
        c.advance(0.5);
        assert_eq!(c.now(), 124.0);
    }

    /// Regression: at large virtual times (long multi-tenant campaigns
    /// reach ~1e6-1e7 s) float jitter between two computations of the
    /// same instant can exceed an absolute 1e-9; the relative tolerance
    /// must accept it as a no-op while still rejecting real regressions.
    #[test]
    fn relative_tolerance_at_large_times() {
        let mut c = VClock::new();
        c.advance_to(1.0e7);
        // ~2e-10 relative error: the old absolute 1e-9 tolerance panicked
        c.advance_to(1.0e7 - 2.0e-3);
        assert_eq!(c.now(), 1.0e7); // clamped, never moved backwards
        c.advance_to(1.0e7 + 1.0);
        assert_eq!(c.now(), 1.0e7 + 1.0);
    }

    #[test]
    #[should_panic]
    fn relative_tolerance_still_rejects_real_backwards_jump() {
        let mut c = VClock::new();
        c.advance_to(1.0e7);
        c.advance_to(1.0e7 - 1.0); // 1 s backwards is a real bug at any scale
    }

    #[test]
    #[should_panic]
    fn small_time_tolerance_not_loosened() {
        let mut c = VClock::new();
        c.advance(1.0);
        // near t=1 the tolerance is still ~1e-9: a 1e-3 jump back panics
        c.advance_to(1.0 - 1.0e-3);
    }
}
