//! Virtual clock for the discrete-event fabric.
//!
//! Everything the paper measured on infrastructure we don't have (ESnet,
//! DCAI machines) is accounted in *virtual seconds* on this clock; real
//! wallclock (PJRT executions) is measured separately by `metrics`.
//! DESIGN.md §7 defines the two-clock discipline.

/// Monotonic virtual clock (seconds).
#[derive(Debug, Clone, Default)]
pub struct VClock {
    now: f64,
}

impl VClock {
    pub fn new() -> VClock {
        VClock { now: 0.0 }
    }

    pub fn now(&self) -> f64 {
        self.now
    }

    /// Advance by a non-negative duration.
    pub fn advance(&mut self, dt: f64) {
        assert!(dt >= 0.0 && dt.is_finite(), "bad clock delta {dt}");
        self.now += dt;
    }

    /// Jump to an absolute time not before the present.
    pub fn advance_to(&mut self, t: f64) {
        assert!(
            t >= self.now - 1e-9,
            "clock would move backwards: {} -> {t}",
            self.now
        );
        self.now = self.now.max(t);
    }
}

/// A span of virtual time, for per-phase breakdowns.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct VSpan {
    pub start: f64,
    pub end: f64,
}

impl VSpan {
    pub fn duration(&self) -> f64 {
        self.end - self.start
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn advances() {
        let mut c = VClock::new();
        c.advance(1.5);
        c.advance(0.5);
        assert_eq!(c.now(), 2.0);
        c.advance_to(5.0);
        assert_eq!(c.now(), 5.0);
        c.advance_to(5.0); // no-op is fine
        assert_eq!(c.now(), 5.0);
    }

    #[test]
    #[should_panic]
    fn rejects_negative_delta() {
        VClock::new().advance(-1.0);
    }

    #[test]
    #[should_panic]
    fn rejects_backwards_jump() {
        let mut c = VClock::new();
        c.advance(10.0);
        c.advance_to(1.0);
    }
}
