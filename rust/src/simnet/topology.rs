//! Facility/link topology of the geographically distributed fabric.
//!
//! The paper's testbed (§5.1): SLAC (experiment + edge) and ALCF (DCAI)
//! joined by ESnet — 100 Gbps backbone, 10 Gbps DTN NICs on each side,
//! ~48 ms round-trip at 3000 km. `paper_topology()` encodes exactly that;
//! config files can define others.

use anyhow::{bail, Context, Result};

use crate::util::Json;

/// Index into `Topology::links`.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash, PartialOrd, Ord)]
pub struct LinkId(pub usize);

/// Index into `Topology::facilities`.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash, PartialOrd, Ord)]
pub struct FacilityId(pub usize);

/// One shared network segment (a DTN NIC or a backbone circuit).
#[derive(Debug, Clone)]
pub struct Link {
    pub name: String,
    /// capacity in bytes/second
    pub capacity_bps: f64,
    /// one-way latency contribution in seconds
    pub latency_s: f64,
}

/// A science facility hosting endpoints (experiment, edge, DCAI, storage).
#[derive(Debug, Clone)]
pub struct Facility {
    pub name: String,
}

/// Static routed topology: facilities, links, and per-pair link paths.
#[derive(Debug, Clone)]
pub struct Topology {
    pub facilities: Vec<Facility>,
    pub links: Vec<Link>,
    /// routes[(a, b)] = ordered links from a to b (symmetric by default)
    routes: Vec<((FacilityId, FacilityId), Vec<LinkId>)>,
}

pub const GBPS: f64 = 1e9 / 8.0; // bytes per second in one Gbit/s

impl Topology {
    pub fn facility(&self, name: &str) -> Result<FacilityId> {
        self.facilities
            .iter()
            .position(|f| f.name == name)
            .map(FacilityId)
            .with_context(|| format!("unknown facility `{name}`"))
    }

    pub fn facility_name(&self, id: FacilityId) -> &str {
        &self.facilities[id.0].name
    }

    pub fn link(&self, id: LinkId) -> &Link {
        &self.links[id.0]
    }

    /// Ordered links between two facilities.
    pub fn route(&self, from: FacilityId, to: FacilityId) -> Result<&[LinkId]> {
        self.routes
            .iter()
            .find(|(pair, _)| *pair == (from, to))
            .map(|(_, r)| r.as_slice())
            .with_context(|| {
                format!(
                    "no route {} -> {}",
                    self.facility_name(from),
                    self.facility_name(to)
                )
            })
    }

    /// Total one-way latency along a route.
    pub fn route_latency(&self, from: FacilityId, to: FacilityId) -> Result<f64> {
        Ok(self
            .route(from, to)?
            .iter()
            .map(|&l| self.link(l).latency_s)
            .sum())
    }

    /// Round-trip time between facilities.
    pub fn rtt(&self, a: FacilityId, b: FacilityId) -> Result<f64> {
        Ok(self.route_latency(a, b)? + self.route_latency(b, a)?)
    }

    /// The paper's SLAC<->ALCF testbed.
    pub fn paper() -> Topology {
        let facilities = vec![
            Facility {
                name: "slac".into(),
            },
            Facility {
                name: "alcf".into(),
            },
        ];
        // 48 ms RTT => 24 ms one-way, dominated by the 3000 km backbone.
        let links = vec![
            Link {
                name: "slac-dtn-nic".into(),
                capacity_bps: 10.0 * GBPS,
                latency_s: 0.5e-3,
            },
            Link {
                name: "esnet-backbone".into(),
                capacity_bps: 100.0 * GBPS,
                latency_s: 23.0e-3,
            },
            Link {
                name: "alcf-dtn-nic".into(),
                capacity_bps: 10.0 * GBPS,
                latency_s: 0.5e-3,
            },
        ];
        let slac = FacilityId(0);
        let alcf = FacilityId(1);
        let fwd = vec![LinkId(0), LinkId(1), LinkId(2)];
        let rev = vec![LinkId(2), LinkId(1), LinkId(0)];
        Topology {
            facilities,
            links,
            routes: vec![((slac, alcf), fwd), ((alcf, slac), rev)],
        }
    }

    /// Register a new facility. Fails on duplicate names so callers can
    /// rely on `facility(name)` staying unambiguous.
    pub fn add_facility(&mut self, name: &str) -> Result<FacilityId> {
        if self.facilities.iter().any(|f| f.name == name) {
            bail!("duplicate facility `{name}`");
        }
        self.facilities.push(Facility { name: name.into() });
        Ok(FacilityId(self.facilities.len() - 1))
    }

    /// Register a new shared link. Fails on duplicate names (link names
    /// key the route grammar in `from_json` and debugging output).
    pub fn add_link(&mut self, name: &str, capacity_bps: f64, latency_s: f64) -> Result<LinkId> {
        if self.links.iter().any(|l| l.name == name) {
            bail!("duplicate link `{name}`");
        }
        self.links.push(Link {
            name: name.into(),
            capacity_bps,
            latency_s,
        });
        Ok(LinkId(self.links.len() - 1))
    }

    /// Register a directed route. Fails if the pair already has one.
    pub fn add_route(&mut self, from: FacilityId, to: FacilityId, path: Vec<LinkId>) -> Result<()> {
        if from == to {
            bail!("route from a facility to itself");
        }
        if path.is_empty() {
            bail!("empty route");
        }
        if self.routes.iter().any(|(pair, _)| *pair == (from, to)) {
            bail!(
                "duplicate route {} -> {}",
                self.facility_name(from),
                self.facility_name(to)
            );
        }
        self.routes.push(((from, to), path));
        Ok(())
    }

    /// Find a link by name.
    pub fn link_by_name(&self, name: &str) -> Result<LinkId> {
        self.links
            .iter()
            .position(|l| l.name == name)
            .map(LinkId)
            .with_context(|| format!("unknown link `{name}`"))
    }

    /// Parse a topology from a JSON config:
    /// `{"facilities": ["a","b"], "links": [{"name","gbps","latency_ms"}],
    ///   "routes": [{"from":"a","to":"b","links":["l1","l2"]}]}`
    /// Routes are added in both the given and reverse direction unless the
    /// reverse is listed explicitly.
    pub fn from_json(j: &Json) -> Result<Topology> {
        let facilities: Vec<Facility> = j
            .get("facilities")
            .as_arr()
            .context("topology missing `facilities`")?
            .iter()
            .map(|f| {
                Ok(Facility {
                    name: f.as_str().context("facility name")?.to_string(),
                })
            })
            .collect::<Result<_>>()?;
        let links: Vec<Link> = j
            .get("links")
            .as_arr()
            .context("topology missing `links`")?
            .iter()
            .map(|l| {
                Ok(Link {
                    name: l.get("name").as_str().context("link name")?.to_string(),
                    capacity_bps: l.get("gbps").as_f64().context("link gbps")? * GBPS,
                    latency_s: l.get("latency_ms").as_f64().context("link latency_ms")? / 1e3,
                })
            })
            .collect::<Result<_>>()?;
        let mut topo = Topology {
            facilities,
            links,
            routes: vec![],
        };
        let link_id = |topo: &Topology, name: &str| -> Result<LinkId> {
            topo.links
                .iter()
                .position(|l| l.name == name)
                .map(LinkId)
                .with_context(|| format!("unknown link `{name}`"))
        };
        for r in j.get("routes").as_arr().context("topology `routes`")? {
            let from = topo.facility(r.get("from").as_str().context("route from")?)?;
            let to = topo.facility(r.get("to").as_str().context("route to")?)?;
            if from == to {
                bail!("route from a facility to itself");
            }
            let path: Vec<LinkId> = r
                .get("links")
                .as_arr()
                .context("route links")?
                .iter()
                .map(|n| link_id(&topo, n.as_str().context("route link name")?))
                .collect::<Result<_>>()?;
            if path.is_empty() {
                bail!("empty route");
            }
            topo.routes.push(((from, to), path.clone()));
            if !topo.routes.iter().any(|(p, _)| *p == (to, from)) {
                let mut rev = path;
                rev.reverse();
                topo.routes.push(((to, from), rev));
            }
        }
        Ok(topo)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn paper_topology_matches_section_5_1() {
        let t = Topology::paper();
        let slac = t.facility("slac").unwrap();
        let alcf = t.facility("alcf").unwrap();
        let rtt = t.rtt(slac, alcf).unwrap();
        assert!((rtt - 0.048).abs() < 1e-9, "rtt {rtt}");
        // narrowest link on the path is the 10 Gbps DTN NIC
        let min_cap = t
            .route(slac, alcf)
            .unwrap()
            .iter()
            .map(|&l| t.link(l).capacity_bps)
            .fold(f64::INFINITY, f64::min);
        assert_eq!(min_cap, 10.0 * GBPS);
    }

    #[test]
    fn json_roundtrip_with_reverse_route() {
        let j = Json::parse(
            r#"{
          "facilities": ["x", "y"],
          "links": [{"name": "l0", "gbps": 1.0, "latency_ms": 10.0}],
          "routes": [{"from": "x", "to": "y", "links": ["l0"]}]
        }"#,
        )
        .unwrap();
        let t = Topology::from_json(&j).unwrap();
        let x = t.facility("x").unwrap();
        let y = t.facility("y").unwrap();
        assert_eq!(t.route(x, y).unwrap(), &[LinkId(0)]);
        assert_eq!(t.route(y, x).unwrap(), &[LinkId(0)]); // implied reverse
        assert!((t.rtt(x, y).unwrap() - 0.020).abs() < 1e-12);
    }

    #[test]
    fn bad_configs_fail() {
        for bad in [
            r#"{"facilities": ["x"], "links": [], "routes": [{"from":"x","to":"x","links":[]}]}"#,
            r#"{"facilities": ["x","y"], "links": [], "routes": [{"from":"x","to":"y","links":["nope"]}]}"#,
            r#"{"facilities": ["x","y"], "links": [], "routes": [{"from":"x","to":"y","links":[]}]}"#,
        ] {
            let j = Json::parse(bad).unwrap();
            assert!(Topology::from_json(&j).is_err(), "{bad}");
        }
    }

    #[test]
    fn unknown_lookups_fail() {
        let t = Topology::paper();
        assert!(t.facility("nersc").is_err());
        let slac = t.facility("slac").unwrap();
        assert!(t.route(slac, slac).is_err());
    }
}
