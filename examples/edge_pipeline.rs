//! End-to-end validation driver (DESIGN.md §6): the full system on a
//! real workload, proving all three layers compose.
//!
//! 1. Synthesize a beamline dataset (Bragg peaks via the Pallas
//!    pseudo-Voigt kernel executed through PJRT).
//! 2. Label it with the *real* conventional analyzer (pseudo-Voigt LM
//!    fitting) — the paper's operation A.
//! 3. Run the DNNTrainerFlow against the remote Cerebras endpoint with
//!    REAL PJRT training (every optimizer step executes the AOT
//!    Pallas/JAX train-step artifact) and log the loss curve.
//! 4. Deploy to the edge and serve a streaming inference workload,
//!    comparing BraggNN's predictions against the conventional fitter.
//! 5. Repeat briefly for CookieNetAE.
//!
//! Results are recorded in EXPERIMENTS.md §End-to-end.
//!
//! Run: `cargo run --release --example edge_pipeline [-- --steps N]`

use anyhow::Result;

use xloop::util::stats::{human_secs, Summary};
use xloop::workflow::{Coordinator, FlowShape, Mode, Scenario, TrainingMode};

fn main() -> Result<()> {
    xloop::util::logging::init();
    let args: Vec<String> = std::env::args().collect();
    let steps: u64 = args
        .iter()
        .position(|a| a == "--steps")
        .and_then(|i| args.get(i + 1))
        .and_then(|s| s.parse().ok())
        .unwrap_or(300);

    println!("=== edge_pipeline: BraggNN, full stack, {steps} real steps ===\n");

    let mut c = Coordinator::paper(42)?;
    c.set_training_mode(TrainingMode::Real {
        steps_override: Some(steps),
    });

    // flow with the labeling action enabled: stage -> label (real LM
    // fitting on a sample + cluster-rate virtual accounting) -> train ->
    // return -> deploy
    let mut scenario = Scenario::table1("braggnn", Mode::RemoteCerebras)?;
    scenario.real_samples = 4096;
    let shape = FlowShape {
        remote: true,
        with_labeling: true,
        ..Default::default()
    };
    let started = std::time::Instant::now();
    let outcome = c.run_retraining(&scenario, Some(shape))?;
    let wall = started.elapsed().as_secs_f64();
    let b = &outcome.breakdown;

    println!("flow actions (virtual time):");
    for r in &outcome.report.records {
        println!(
            "  {:<14} {:>10}  [{:?}]",
            r.id,
            human_secs(r.duration()),
            r.status
        );
    }
    println!("\nend-to-end (virtual): {}", human_secs(b.end_to_end_s));
    println!("wallclock (real)    : {}", human_secs(wall));

    // loss curve from the real training run
    let trained = c.world.trained("braggnn")?;
    let report = trained.report.as_ref().expect("real training ran");
    println!(
        "\nloss curve ({} steps, {} real, {} inside PJRT):",
        report.steps,
        human_secs(report.real_secs),
        human_secs(report.exec_secs)
    );
    for (step, loss) in &report.losses {
        let bar = "#".repeat(((loss / report.first_loss).min(1.0) * 48.0) as usize);
        println!("  step {step:>5}  loss {loss:.6}  {bar}");
    }
    anyhow::ensure!(
        report.final_loss < report.first_loss * 0.25,
        "loss did not converge: {} -> {}",
        report.first_loss,
        report.final_loss
    );

    // edge accuracy: BraggNN vs the conventional fitter on fresh peaks
    println!("\n=== edge serving + accuracy vs conventional analyzer ===\n");
    let fresh = xloop::data::bragg::generate(
        &xloop::data::BraggConfig::default(),
        2048,
        777,
    )?;
    let serve = c.world.edge.serve_stream(&fresh, 8)?;
    println!(
        "served {} samples: mean {} p99 {} per batch of {}, {} samples/s real, modeled edge {}",
        serve.samples,
        human_secs(serve.real_mean_s),
        human_secs(serve.real_p99_s),
        fresh.n.min(512),
        serve.real_throughput as u64,
        human_secs(serve.virtual_total_s),
    );

    let meta = c.world.registry.get("braggnn")?.clone();
    let b_sz = meta.infer_batch;
    let idx: Vec<usize> = (0..b_sz).collect();
    let (x, y) = fresh.gather_batch(&idx)?;
    let pred = c.world.edge.infer_batch(&x)?;
    let mut nn_err = Summary::new();
    for i in 0..b_sz {
        // px error: predictions and labels are center/10
        let dx = (pred.data()[2 * i] - y.data()[2 * i]) * 10.0;
        let dy = (pred.data()[2 * i + 1] - y.data()[2 * i + 1]) * 10.0;
        nn_err.add(((dx * dx + dy * dy) as f64).sqrt());
    }
    let px = 11 * 11;
    let (fits, timing) =
        xloop::analysis::label_patches_timed(&fresh.x[..b_sz * px], b_sz, 11, 11)?;
    let per_peak = timing.per_peak_wall_s();
    let mut fit_err = Summary::new();
    for (i, fit) in fits.iter().enumerate() {
        let (fx, fy) = fit.center();
        let dx = fx - (y.data()[2 * i] * 10.0) as f64;
        let dy = fy - (y.data()[2 * i + 1] * 10.0) as f64;
        fit_err.add((dx * dx + dy * dy).sqrt());
    }
    println!(
        "BraggNN mean center error : {:.3} px (after {steps} steps)",
        nn_err.mean()
    );
    println!(
        "pseudo-Voigt fit error    : {:.3} px at {:.2} ms/peak wall, {:.2} ms/peak CPU \
         ({} pool threads, {:.2}x realized — real C(A) here)",
        fit_err.mean(),
        per_peak * 1e3,
        timing.per_peak_cpu_s() * 1e3,
        timing.threads,
        timing.speedup()
    );
    let nn_us = serve.real_mean_s / b_sz as f64 * 1e6;
    let edge_us = serve.virtual_total_s / serve.samples as f64 * 1e6;
    println!(
        "speed (this CPU, interpret-mode kernels): BraggNN {nn_us:.1} µs/peak vs fitter {:.0} µs/peak ({:.2}x)",
        per_peak * 1e6,
        per_peak * 1e6 / nn_us
    );
    println!(
        "speed (modeled edge accelerator)        : BraggNN {edge_us:.2} µs/peak vs fitter {:.0} µs/peak ({:.0}x — the paper's >200x regime)",
        per_peak * 1e6,
        per_peak * 1e6 / edge_us
    );

    // --- CookieNetAE, shorter (its steps are ~40x costlier on CPU) ---
    println!("\n=== CookieNetAE through the same flow (short run) ===\n");
    let mut c2 = Coordinator::paper(43)?;
    c2.set_training_mode(TrainingMode::Real {
        steps_override: Some((steps / 20).max(5)),
    });
    let scenario2 = Scenario::table1("cookienetae", Mode::RemoteCerebras)?;
    let outcome2 = c2.run_retraining(&scenario2, None)?;
    let trained2 = c2.world.trained("cookienetae")?;
    let rep2 = trained2.report.as_ref().unwrap();
    println!(
        "cookienetae: {} steps, loss {:.5} -> {:.5}, e2e (virtual) {}",
        rep2.steps,
        rep2.first_loss,
        rep2.final_loss,
        human_secs(outcome2.breakdown.end_to_end_s)
    );
    anyhow::ensure!(rep2.final_loss < rep2.first_loss, "cookie loss went up");

    println!("\nedge_pipeline OK");
    Ok(())
}
