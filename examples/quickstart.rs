//! Quickstart: the shortest path through the public API.
//!
//! Retrains BraggNN on the remote (simulated) Cerebras through the full
//! DNNTrainerFlow — stage data over the WAN, train with real PJRT steps,
//! return the model, deploy to the edge — then answers one inference
//! batch.
//!
//! Run: `cargo run --release --example quickstart`

use anyhow::Result;

use xloop::util::stats::human_secs;
use xloop::workflow::{Coordinator, Mode, Scenario, TrainingMode};

fn main() -> Result<()> {
    xloop::util::logging::init();
    println!(
        "analysis/generation pool: {} worker thread(s) (XLOOP_THREADS to override)",
        xloop::pool::global().threads()
    );

    // 1. Bring up the paper fabric: SLAC + ALCF, DTNs, faas endpoints,
    //    accelerator models, flow engine, PJRT runtime.
    let mut coordinator = Coordinator::paper(42)?;

    // 2. Ask for real training (a short run — the loss curve is real).
    coordinator.set_training_mode(TrainingMode::Real {
        steps_override: Some(30),
    });

    // 3. Run the paper's retraining flow on the remote Cerebras.
    let scenario = Scenario::table1("braggnn", Mode::RemoteCerebras)?;
    let outcome = coordinator.run_retraining(&scenario, None)?;
    let b = &outcome.breakdown;

    println!("retrained {} via {}", b.model, b.mode_label);
    println!("  data transfer : {}", human_secs(b.data_transfer_s.unwrap()));
    println!(
        "  training      : {} (virtual; {} real PJRT steps, final loss {:.5})",
        human_secs(b.training_s),
        b.real_steps,
        b.final_loss.unwrap()
    );
    println!("  model transfer: {}", human_secs(b.model_transfer_s.unwrap()));
    println!("  end-to-end    : {}", human_secs(b.end_to_end_s));

    // 4. The edge host now serves the new model.
    let dataset = coordinator.world.dataset("braggnn-train")?.clone();
    let report = coordinator.world.edge.serve_stream(&dataset, 4)?;
    println!(
        "edge serving v{}: {} samples, mean latency {}, {} samples/s (real)",
        report.version,
        report.samples,
        human_secs(report.real_mean_s),
        report.real_throughput as u64
    );
    Ok(())
}
