//! Fig. 4 reproduction: conventional vs ML-surrogate processing time as
//! a function of dataset size, with the analytical crossover point and a
//! sensitivity sweep over the shipped fraction p and the training time T.
//!
//! Run: `cargo run --release --example crossover`

use anyhow::Result;

use xloop::costmodel::CostParams;

fn main() -> Result<()> {
    xloop::util::logging::init();
    let params = CostParams::paper();

    println!("Fig. 4 — conventional vs ML-surrogate (paper §4.2 constants)\n");
    println!(
        "{:>12} {:>16} {:>16} {:>8}",
        "N peaks", "conventional(s)", "ML surrogate(s)", "winner"
    );
    let mut n = 1e3;
    while n <= 1e9 {
        let fc = params.f_conventional_us(n) / 1e6;
        let fml = params.f_ml_us(n) / 1e6;
        println!(
            "{n:>12.0e} {fc:>16.2} {fml:>16.2} {:>8}",
            if fc <= fml { "conv" } else { "ML" }
        );
        n *= 10.0;
    }
    let cross = params.crossover()?;
    println!(
        "\ncrossover: N* = {:.3e} peaks (fixed cost {:.1} s amortized at {:.2} µs/peak gain)",
        cross.n_star,
        cross.fixed_cost_us / 1e6,
        cross.per_datum_gain_us
    );

    println!("\n=== sensitivity: crossover vs shipped fraction p ===\n");
    println!("{:>6} {:>14}", "p", "N* (peaks)");
    for p10 in [1, 2, 5, 8] {
        let mut c = params;
        c.p = p10 as f64 / 10.0;
        match c.crossover() {
            Ok(r) => println!("{:>6.1} {:>14.3e}", c.p, r.n_star),
            Err(e) => println!("{:>6.1} {:>14}", p10 as f64 / 10.0, format!("never ({e})")),
        }
    }

    println!("\n=== sensitivity: crossover vs training time T (the DCAI argument) ===\n");
    println!("{:>14} {:>14}  device", "T (s)", "N* (peaks)");
    for (t, device) in [
        (19.0, "Cerebras (entire wafer)"),
        (139.0, "SambaNova 1-RDU"),
        (1102.0, "local V100"),
    ] {
        let mut c = params;
        c.t_train_us = t * 1e6;
        let r = c.crossover()?;
        println!("{t:>14.0} {:>14.3e}  {device}", r.n_star);
    }
    println!(
        "\nfaster remote training pushes the crossover down ~58x: exactly the paper's case \
         for shipping training to a DCAI system."
    );
    Ok(())
}
