//! Table 1 reproduction: the end-to-end retraining time breakdown for
//! every (model, mode) pair the paper measured, printed side by side
//! with the paper's numbers.
//!
//! Run: `cargo run --release --example remote_retrain [-- --real]`
//! (`--real` also executes real PJRT training steps per cell.)

use anyhow::Result;

use xloop::workflow::{render_table1, Coordinator, Scenario, TrainingMode};

/// Paper Table 1 values for the comparison column.
fn paper_reference(model: &str, mode_label: &str) -> Option<(f64, f64, f64, f64)> {
    // (data transfer, training, model transfer, end-to-end)
    match (model, mode_label) {
        ("braggnn", l) if l.starts_with("Local") => Some((0.0, 1102.0, 0.0, 1102.0)),
        ("braggnn", l) if l.contains("Cerebras") => Some((7.0, 19.0, 5.0, 31.0)),
        ("braggnn", l) if l.contains("SambaNova") => Some((7.0, 139.0, 5.0, 151.0)),
        ("cookienetae", l) if l.starts_with("Local") => Some((0.0, 517.0, 0.0, 517.0)),
        ("cookienetae", l) if l.contains("Cerebras") => Some((5.0, 6.0, 4.0, 15.0)),
        ("cookienetae", l) if l.contains("multi-GPU") => Some((5.0, 88.0, 4.0, 97.0)),
        _ => None,
    }
}

fn main() -> Result<()> {
    xloop::util::logging::init();
    let real = std::env::args().any(|a| a == "--real");

    let mut rows = Vec::new();
    for scenario in Scenario::table1_grid() {
        let mut c = Coordinator::paper(42)?;
        c.set_training_mode(if real {
            TrainingMode::Real {
                steps_override: None,
            }
        } else {
            TrainingMode::VirtualOnly
        });
        eprintln!("running {} / {} ...", scenario.model, scenario.mode.label());
        let outcome = c.run_retraining(&scenario, None)?;
        rows.push(outcome.breakdown);
    }

    println!("\n=== Table 1 (reproduced, virtual seconds) ===\n");
    print!("{}", render_table1(&rows));

    println!("\n=== paper vs measured (end-to-end) ===\n");
    println!(
        "{:<34} {:<12} {:>10} {:>10} {:>8}",
        "Mode", "Model", "paper (s)", "ours (s)", "ratio"
    );
    for r in &rows {
        if let Some((_, _, _, e2e)) = paper_reference(&r.model, &r.mode_label) {
            println!(
                "{:<34} {:<12} {:>10.0} {:>10.1} {:>8.2}",
                r.mode_label,
                r.model,
                e2e,
                r.end_to_end_s,
                r.end_to_end_s / e2e
            );
        }
    }

    // headline claim check
    let local = rows
        .iter()
        .find(|r| r.model == "braggnn" && r.mode_label.starts_with("Local"))
        .unwrap();
    let cerebras = rows
        .iter()
        .find(|r| r.model == "braggnn" && r.mode_label.contains("Cerebras"))
        .unwrap();
    let speedup = local.end_to_end_s / cerebras.end_to_end_s;
    println!(
        "\nheadline: remote DCAI is {speedup:.1}x faster end-to-end than the local GPU \
         (paper: >30x) — {}",
        if speedup > 30.0 { "REPRODUCED" } else { "NOT reproduced" }
    );
    Ok(())
}
