//! Fig. 3 reproduction: Globus-style WAN transfer throughput between the
//! SLAC and ALCF DTNs as a function of file concurrency, both directions,
//! plus the fitted `T = x/v + S` linear model of §4.1.
//!
//! Run: `cargo run --release --example transfer_sweep`

use anyhow::Result;

use xloop::simnet::VClock;
use xloop::transfer::{LinearModel, Observation, TransferRequest, TransferService};
use xloop::util::stats::human_bytes;

fn sweep(src: &str, dst: &str, bytes: u64, files: usize) -> Result<Vec<(usize, f64)>> {
    let mut out = Vec::new();
    for k in [1usize, 2, 4, 8, 16, 32] {
        if k > files {
            break;
        }
        let mut svc = TransferService::paper(7);
        let mut clock = VClock::new();
        let mut req =
            TransferRequest::split_even("sweep", src.into(), dst.into(), bytes, files);
        req.concurrency = Some(k);
        let rep = svc.execute(&mut clock, &req)?;
        out.push((k, rep.throughput_bps()));
    }
    Ok(out)
}

fn main() -> Result<()> {
    xloop::util::logging::init();
    let bytes: u64 = 25_000_000_000; // 25 GB, Fig. 3-scale payload
    let files = 32;

    println!(
        "Fig. 3 — transfer throughput, {} in {files} files (10 Gbps DTN NICs, 48 ms RTT)\n",
        human_bytes(bytes as f64)
    );
    let fwd = sweep("slac#dtn", "alcf#dtn", bytes, files)?;
    let back = sweep("alcf#dtn", "slac#dtn", bytes, files)?;
    println!(
        "{:>12} {:>18} {:>18}",
        "concurrency", "SLAC->ALCF (GB/s)", "ALCF->SLAC (GB/s)"
    );
    for ((k, f), (_, b)) in fwd.iter().zip(&back) {
        let bar = "#".repeat((f / 1e9 * 24.0) as usize);
        println!("{k:>12} {:>18.3} {:>18.3}   {bar}", f / 1e9, b / 1e9);
    }
    println!("\npaper: >1 GB/s with concurrent files; ALCF->SLAC slightly slower (Fig. 3)");

    // §4.1 linear model fitted from simulated transfers
    println!("\n=== fitted linear model T = x/v + S (paper §4.1) ===\n");
    let mut svc = TransferService::paper(11);
    let mut obs = Vec::new();
    for &(gb, n) in &[(1.0, 8usize), (2.0, 16), (5.0, 16), (10.0, 32), (2.0, 64), (20.0, 8)] {
        let mut clock = VClock::new();
        let mut req = TransferRequest::split_even(
            "fit",
            "slac#dtn".into(),
            "alcf#dtn".into(),
            (gb * 1e9) as u64,
            n,
        );
        req.concurrency = Some(8);
        let rep = svc.execute(&mut clock, &req)?;
        obs.push(Observation {
            bytes: rep.bytes as f64,
            n_files: n as f64,
            seconds: rep.duration(),
        });
    }
    let model = LinearModel::fit(&obs)?;
    println!(
        "v = {:.3} GB/s, S = {:.2} s + {:.3} s/file (mean rel. error {:.1}%)",
        model.rate_bps / 1e9,
        model.startup_s,
        model.per_file_s,
        model.mean_rel_error(&obs) * 100.0
    );
    println!(
        "prediction for the Table 1 BraggNN staging (3.6 GB, 16 files): {:.1} s (simulated: ~7.4 s)",
        model.predict(3.6e9, 16.0)
    );
    Ok(())
}
