#!/usr/bin/env python3
"""Parse `cargo bench --bench micro` output into BENCH_ci.json.

The bench harness (rust/benches/harness.rs) prints one line per bench:

    <name padded to 52>  time: [<min> <mean> <mean+std>]  (p95 <p95>, <N> iters)

with times humanized as ns/µs/ms/s, grouped under `=== <title> ===`
section headers. This script turns that into a JSON document the CI
uploads as an artifact, with the runner's CPU recorded next to the
numbers (runner hardware varies run to run — these are trend lines for
EXPERIMENTS.md §Perf, not absolute truth).

Usage: parse_bench.py <bench-output.txt> <out.json>
"""

import json
import os
import re
import sys

UNIT = {"ns": 1e-9, "µs": 1e-6, "us": 1e-6, "ms": 1e-3, "s": 1.0}

LINE = re.compile(
    r"^(?P<name>.*?)\s+time:\s+\["
    r"(?P<min>[\d.]+)\s+(?P<minu>ns|µs|us|ms|s)\s+"
    r"(?P<mean>[\d.]+)\s+(?P<meanu>ns|µs|us|ms|s)\s+"
    r"(?P<hi>[\d.]+)\s+(?P<hiu>ns|µs|us|ms|s)\]\s+"
    r"\(p95\s+(?P<p95>[\d.]+)\s+(?P<p95u>ns|µs|us|ms|s),\s+(?P<iters>\d+)\s+iters\)"
)
GROUP = re.compile(r"^===\s+(?P<title>.*?)\s+===$")
# whole-engine scale lines from the campaign group (and the CLI's stderr):
#     campaign-scale: <users> users in <wall> s = <rate> users/s
SCALE = re.compile(
    r"^campaign-scale:\s+(?P<users>\d+)\s+users in\s+"
    r"(?P<wall>[\d.]+)\s+s = (?P<rate>[\d.]+)\s+users/s$"
)
# replica-vs-windowed comparison lines from the campaign-sync group:
#     campaign-sync: <mode> <users> users in <wall> s = <rate> users/s [(N windows)]
SYNC = re.compile(
    r"^campaign-sync:\s+(?P<mode>replica|windowed)\s+(?P<users>\d+)\s+users in\s+"
    r"(?P<wall>[\d.]+)\s+s = (?P<rate>[\d.]+)\s+users/s"
    r"(?:\s+\((?P<windows>\d+)\s+windows?\))?$"
)


def cpu_model():
    try:
        with open("/proc/cpuinfo") as f:
            for line in f:
                if line.lower().startswith("model name"):
                    return line.split(":", 1)[1].strip()
    except OSError:
        pass
    return "unknown"


def main():
    if len(sys.argv) != 3:
        sys.exit(__doc__)
    src, dst = sys.argv[1], sys.argv[2]
    group = None
    benches = []
    scale = []
    sync_scale = []
    with open(src, encoding="utf-8") as f:
        for raw in f:
            line = raw.rstrip("\n")
            g = GROUP.match(line.strip())
            if g:
                group = g.group("title")
                continue
            s = SCALE.match(line.strip())
            if s:
                scale.append(
                    {
                        "group": group,
                        "users": int(s.group("users")),
                        "wall_s": float(s.group("wall")),
                        "users_per_s": float(s.group("rate")),
                    }
                )
                continue
            y = SYNC.match(line.strip())
            if y:
                sync_scale.append(
                    {
                        "group": group,
                        "mode": y.group("mode"),
                        "users": int(y.group("users")),
                        "wall_s": float(y.group("wall")),
                        "users_per_s": float(y.group("rate")),
                        "windows": int(y.group("windows"))
                        if y.group("windows")
                        else None,
                    }
                )
                continue
            m = LINE.match(line)
            if not m:
                continue
            to_s = lambda v, u: float(v) * UNIT[u]
            benches.append(
                {
                    "group": group,
                    "name": m.group("name").strip(),
                    "min_s": to_s(m.group("min"), m.group("minu")),
                    "mean_s": to_s(m.group("mean"), m.group("meanu")),
                    "mean_plus_std_s": to_s(m.group("hi"), m.group("hiu")),
                    "p95_s": to_s(m.group("p95"), m.group("p95u")),
                    "iters": int(m.group("iters")),
                }
            )
    doc = {
        "source": os.path.basename(src),
        "cpu": cpu_model(),
        "nproc": os.cpu_count(),
        "threads_env": os.environ.get("XLOOP_THREADS", ""),
        "benches": benches,
        "users_per_wall_second": scale,
        "sync_users_per_wall_second": sync_scale,
    }
    with open(dst, "w", encoding="utf-8") as f:
        json.dump(doc, f, indent=1)
    print(
        f"[parse_bench] {len(benches)} benches, {len(scale)} scale points,"
        f" {len(sync_scale)} sync points -> {dst} (cpu: {doc['cpu']})"
    )
    if not scale:
        # campaign-scale runs after the PJRT artifacts gate, so an
        # artifact-less bench transcript legitimately has no such lines.
        print("[parse_bench] note: no campaign-scale lines (artifacts absent?)")
    if not benches:
        sys.exit("no bench lines parsed — harness output format changed?")


if __name__ == "__main__":
    main()
