"""Tiled Pallas matmul — the MXU workhorse for both models.

TPU mapping (see DESIGN.md §Hardware-Adaptation): the grid tiles the
output (M, N) space; each program instance owns one (BM, BN) output block
and loops over K in (BK)-wide slabs held in VMEM, accumulating in a f32
VMEM scratch block. BM/BN default to 128 to line up with the 128x128 MXU
systolic array; BK to 128 lanes. Inputs that do not divide the block
sizes are zero-padded at the wrapper level (zero rows/cols do not perturb
the product) and the result is sliced back.

Backward: matmul is wrapped in `jax.custom_vjp` whose cotangents are
themselves Pallas matmuls (dA = g @ B^T, dB = A^T @ g), so the entire
training graph -- forward AND backward -- flows through this kernel.

interpret=True everywhere: the CPU PJRT plugin cannot execute Mosaic
custom-calls; interpret mode lowers the kernel to plain HLO loops, which
is what `make artifacts` ships to the rust runtime.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

# Default block sizes, chosen for the MXU (128x128) and a VMEM budget of
# (BM*BK + BK*BN + BM*BN) * 4B = 192 KiB << 16 MiB, leaving room for
# double buffering of the K-slab stream.
BLOCK_M = 128
BLOCK_N = 128
BLOCK_K = 128


def _matmul_kernel(a_ref, b_ref, o_ref, acc_ref, *, n_k: int):
    """One (BM, BN) output block; grid = (M/BM, N/BN, K/BK).

    K is the innermost (minor) grid axis, so consecutive program steps
    stream K-slabs for the same output block; `acc_ref` (VMEM scratch)
    carries the partial sum across those steps.
    """
    k = pl.program_id(2)

    @pl.when(k == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    acc_ref[...] += jnp.dot(
        a_ref[...], b_ref[...], preferred_element_type=jnp.float32
    )

    @pl.when(k == n_k - 1)
    def _flush():
        o_ref[...] = acc_ref[...]


def _pad_to(x: jnp.ndarray, axis: int, mult: int) -> jnp.ndarray:
    size = x.shape[axis]
    rem = (-size) % mult
    if rem == 0:
        return x
    pad = [(0, 0)] * x.ndim
    pad[axis] = (0, rem)
    return jnp.pad(x, pad)


@functools.partial(
    jax.jit, static_argnames=("block_m", "block_n", "block_k", "interpret")
)
def matmul_pallas(
    a: jnp.ndarray,
    b: jnp.ndarray,
    *,
    block_m: int = BLOCK_M,
    block_n: int = BLOCK_N,
    block_k: int = BLOCK_K,
    interpret: bool = True,
) -> jnp.ndarray:
    """Raw Pallas (M,K)x(K,N) product without the custom_vjp wrapper."""
    if a.ndim != 2 or b.ndim != 2:
        raise ValueError(f"matmul_pallas expects 2-D operands, got {a.shape} x {b.shape}")
    m, k = a.shape
    k2, n = b.shape
    if k != k2:
        raise ValueError(f"contraction mismatch: {a.shape} x {b.shape}")

    # Shrink blocks for tiny operands so the grid is never empty and the
    # padding overhead stays bounded.
    block_m = min(block_m, max(8, m))
    block_n = min(block_n, max(8, n))
    block_k = min(block_k, max(8, k))
    # Long-K contractions (conv backward-dw: K = B*OH*OW ~ 10k) would pay
    # one grid step per 128-slab; widen the K slab instead. VMEM check:
    # 128x2048 + 2048x128 + 128x128 f32 = 2.1 MiB — double-buffers fine
    # inside 16 MiB (perf log: EXPERIMENTS.md §Perf, 6x on the train step).
    if k > 8 * block_k:
        block_k = min(2048, k)

    a = _pad_to(_pad_to(a.astype(jnp.float32), 0, block_m), 1, block_k)
    b = _pad_to(_pad_to(b.astype(jnp.float32), 0, block_k), 1, block_n)
    mp, kp = a.shape
    _, np_ = b.shape
    n_k = kp // block_k

    grid = (mp // block_m, np_ // block_n, n_k)
    out = pl.pallas_call(
        functools.partial(_matmul_kernel, n_k=n_k),
        grid=grid,
        in_specs=[
            pl.BlockSpec((block_m, block_k), lambda i, j, kk: (i, kk)),
            pl.BlockSpec((block_k, block_n), lambda i, j, kk: (kk, j)),
        ],
        out_specs=pl.BlockSpec((block_m, block_n), lambda i, j, kk: (i, j)),
        out_shape=jax.ShapeDtypeStruct((mp, np_), jnp.float32),
        scratch_shapes=[pltpu.VMEM((block_m, block_n), jnp.float32)],
        interpret=interpret,
    )(a, b)
    return out[:m, :n]


@jax.custom_vjp
def matmul(a: jnp.ndarray, b: jnp.ndarray) -> jnp.ndarray:
    """Differentiable Pallas matmul: a [M,K] @ b [K,N] -> [M,N] (f32)."""
    return matmul_pallas(a, b)


def _matmul_fwd(a, b):
    return matmul_pallas(a, b), (a, b)


def _matmul_bwd(res, g):
    a, b = res
    # Cotangents are Pallas matmuls too: the backward pass exercises the
    # same MXU kernel. Transposes stay at the jnp level (layout change,
    # fused by XLA into the operand feed).
    da = matmul_pallas(g, b.T)
    db = matmul_pallas(a.T, g)
    return da, db


matmul.defvjp(_matmul_fwd, _matmul_bwd)


def dense(x: jnp.ndarray, w: jnp.ndarray, b: jnp.ndarray) -> jnp.ndarray:
    """Affine layer on the Pallas matmul; bias add is a fused XLA op."""
    return matmul(x, w) + b[None, :]
