"""Pallas pseudo-Voigt surface evaluator — the data-simulation hot-spot.

The paper's operation **S** (simulate a datum) for HEDM is the synthesis
of Bragg-peak detector patches, whose physics shape is the 2-D
pseudo-Voigt profile that the conventional analysis **A** also fits. This
kernel batch-evaluates P surfaces on an HxW pixel grid.

TPU mapping: a pure-VPU elementwise kernel — no MXU involvement. The grid
tiles the peak batch; each instance broadcasts its 7 scalar parameters
over an (H, W) lane block (8x128 VPU lanes line up with the 11x11 and
16x128 patch shapes after padding). Everything (params slab + output
block) is trivially VMEM-resident.

The rust data generator executes the AOT-lowered form of this kernel via
PJRT (`artifacts/pv_surface.hlo.txt`) so the L1 kernel sits on the
runtime data path, then adds detector noise rust-side.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

BLOCK_P = 64


def _pv_kernel(p_ref, o_ref):
    """p_ref: [BP, 7]; o_ref: [BP, H, W]."""
    _, h, w = o_ref.shape
    amp = p_ref[:, 0][:, None, None]
    x0 = p_ref[:, 1][:, None, None]
    y0 = p_ref[:, 2][:, None, None]
    sx = p_ref[:, 3][:, None, None]
    sy = p_ref[:, 4][:, None, None]
    eta = p_ref[:, 5][:, None, None]
    bg = p_ref[:, 6][:, None, None]
    rows = jax.lax.broadcasted_iota(jnp.float32, (1, h, w), 1)
    cols = jax.lax.broadcasted_iota(jnp.float32, (1, h, w), 2)
    dx = cols - x0
    dy = rows - y0
    gx = dx * dx / (sx * sx)
    gy = dy * dy / (sy * sy)
    gauss = jnp.exp(-0.5 * (gx + gy))
    lorentz = 1.0 / (1.0 + gx + gy)
    o_ref[...] = amp * (eta * lorentz + (1.0 - eta) * gauss) + bg


@functools.partial(
    jax.jit, static_argnames=("height", "width", "block_p", "interpret")
)
def pseudo_voigt(
    params: jnp.ndarray,
    *,
    height: int,
    width: int,
    block_p: int = BLOCK_P,
    interpret: bool = True,
) -> jnp.ndarray:
    """Batched pseudo-Voigt surfaces.

    params: [P, 7] = (amp, x0, y0, sigma_x, sigma_y, eta, bg);
    returns [P, height, width] f32. Matches `ref.pseudo_voigt_ref`.
    """
    if params.ndim != 2 or params.shape[1] != 7:
        raise ValueError(f"params must be [P, 7], got {params.shape}")
    p = params.shape[0]
    block_p = min(block_p, max(1, p))
    pad = (-p) % block_p
    if pad:
        # Padded rows have sigma=0 -> guard with a benign sigma of 1.
        filler = jnp.tile(
            jnp.array([[0.0, 0.0, 0.0, 1.0, 1.0, 0.5, 0.0]], jnp.float32),
            (pad, 1),
        )
        params = jnp.concatenate([params.astype(jnp.float32), filler])
    pp = params.shape[0]

    out = pl.pallas_call(
        _pv_kernel,
        grid=(pp // block_p,),
        in_specs=[pl.BlockSpec((block_p, 7), lambda i: (i, 0))],
        out_specs=pl.BlockSpec((block_p, height, width), lambda i: (i, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((pp, height, width), jnp.float32),
        interpret=interpret,
    )(params.astype(jnp.float32))
    return out[:p]
