"""L1: Pallas kernels for the paper's compute hot-spots.

- `matmul` / `dense`  — tiled MXU matmul with custom_vjp (fwd+bwd Pallas)
- `conv2d` / `conv2d_bias` — conv as shifted matmuls, custom_vjp likewise
- `pseudo_voigt` — VPU elementwise Bragg-peak surface synthesis

`ref.py` carries the pure-jnp oracles pytest checks every kernel against.
"""

from .conv2d import conv2d, conv2d_bias, conv2d_pallas
from .matmul import dense, matmul, matmul_pallas
from .pseudo_voigt import pseudo_voigt

__all__ = [
    "conv2d",
    "conv2d_bias",
    "conv2d_pallas",
    "dense",
    "matmul",
    "matmul_pallas",
    "pseudo_voigt",
]
