"""Pure-jnp oracles for every Pallas kernel (L1 correctness contract).

Each function here is the mathematical definition the corresponding Pallas
kernel in this package must match to within float tolerance. pytest
(python/tests/test_kernels.py) sweeps shapes/dtypes with hypothesis and
asserts `assert_allclose(kernel(...), ref(...))`.
"""

import jax
import jax.numpy as jnp


def matmul_ref(a: jnp.ndarray, b: jnp.ndarray) -> jnp.ndarray:
    """Plain (M,K)x(K,N) matrix product, f32 accumulation."""
    return jnp.matmul(a.astype(jnp.float32), b.astype(jnp.float32))


def dense_ref(x: jnp.ndarray, w: jnp.ndarray, b: jnp.ndarray) -> jnp.ndarray:
    """Affine layer: x @ w + b."""
    return matmul_ref(x, w) + b[None, :]


def conv2d_ref(x: jnp.ndarray, w: jnp.ndarray) -> jnp.ndarray:
    """VALID, stride-1, NHWC x HWIO convolution (cross-correlation).

    x: [B, H, W, Cin], w: [KH, KW, Cin, Cout] -> [B, H-KH+1, W-KW+1, Cout]
    """
    return jax.lax.conv_general_dilated(
        x.astype(jnp.float32),
        w.astype(jnp.float32),
        window_strides=(1, 1),
        padding="VALID",
        dimension_numbers=("NHWC", "HWIO", "NHWC"),
    )


def pseudo_voigt_ref(params: jnp.ndarray, height: int, width: int) -> jnp.ndarray:
    """Batched 2-D pseudo-Voigt surface on a pixel grid.

    params: [P, 7] columns (amp, x0, y0, sigma_x, sigma_y, eta, bg).
    Returns [P, height, width] with
        pv = amp * (eta * L + (1 - eta) * G) + bg
        G  = exp(-0.5 * (dx^2 / sx^2 + dy^2 / sy^2))
        L  = 1 / (1 + dx^2 / sx^2 + dy^2 / sy^2)
    where dx = col - x0, dy = row - y0. This must match, formula-for-formula,
    `rust/src/analysis/pseudo_voigt.rs` (the conventional baseline) and
    `rust/src/data/bragg.rs` (the synthetic generator).
    """
    amp, x0, y0, sx, sy, eta, bg = [params[:, i][:, None, None] for i in range(7)]
    rows = jnp.arange(height, dtype=jnp.float32)[None, :, None]
    cols = jnp.arange(width, dtype=jnp.float32)[None, None, :]
    dx = cols - x0
    dy = rows - y0
    gx = dx * dx / (sx * sx)
    gy = dy * dy / (sy * sy)
    gauss = jnp.exp(-0.5 * (gx + gy))
    lorentz = 1.0 / (1.0 + gx + gy)
    return amp * (eta * lorentz + (1.0 - eta) * gauss) + bg
