"""Pallas conv2d as a sum of shifted MXU matmuls.

TPU mapping (DESIGN.md §Hardware-Adaptation): instead of the CUDA
thread-per-output-pixel formulation, a VALID stride-1 NHWC conv is
decomposed into KH*KW shifted GEMMs:

    out[n, oh, ow, co] = sum_{kh, kw} x[n, oh+kh, ow+kw, :] @ w[kh, kw, :, :]

Each program instance owns a batch block; the (kh, kw) loop is unrolled at
trace time (9 iterations for 3x3), and every iteration is a
(BB*OH*OW, Cin) x (Cin, Cout) contraction that feeds the 128x128 systolic
array. The input block, the full filter, and the f32 accumulator all live
in VMEM; for the paper's models the largest block is
CookieNetAE's 4x16x128x96 input slab + 3x3x96x96 filter + accumulator
≈ 3.1 MiB + 0.3 MiB + 3.1 MiB — comfortably inside 16 MiB with
double-buffering headroom.

Padding (SAME) is applied by the caller with `jnp.pad` -- pad has a
trivial, XLA-fused vjp (slice), keeping the kernel itself VALID-only.

Backward, via custom_vjp, reuses Pallas primitives exclusively:
  dx = conv2d(full_pad(g), rot180(w).swap(io))   -- this same kernel
  dw[kh,kw] = x_shift(kh,kw)^T @ g               -- the Pallas matmul
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from .matmul import matmul_pallas

# Batch block: instances stream over the batch dimension.
BLOCK_B = 8


def _conv2d_kernel(x_ref, w_ref, o_ref, *, kh: int, kw: int):
    """One batch block of VALID conv via unrolled shifted matmuls."""
    bb, hp, wp, cin = x_ref.shape
    oh = hp - kh + 1
    ow = wp - kw + 1
    cout = w_ref.shape[-1]
    acc = jnp.zeros((bb * oh * ow, cout), dtype=jnp.float32)
    for i in range(kh):
        for j in range(kw):
            xs = x_ref[:, i : i + oh, j : j + ow, :].reshape(bb * oh * ow, cin)
            acc += jnp.dot(
                xs, w_ref[i, j], preferred_element_type=jnp.float32
            )
    o_ref[...] = acc.reshape(bb, oh, ow, cout)


@functools.partial(jax.jit, static_argnames=("block_b", "interpret"))
def conv2d_pallas(
    x: jnp.ndarray,
    w: jnp.ndarray,
    *,
    block_b: int = BLOCK_B,
    interpret: bool = True,
) -> jnp.ndarray:
    """Raw Pallas VALID stride-1 NHWC conv (no vjp wrapper).

    x: [B, H, W, Cin], w: [KH, KW, Cin, Cout] -> [B, OH, OW, Cout].
    """
    if x.ndim != 4 or w.ndim != 4:
        raise ValueError(f"conv2d_pallas expects NHWC x HWIO, got {x.shape} x {w.shape}")
    b, h, wd, cin = x.shape
    kh, kw, cin2, cout = w.shape
    if cin != cin2:
        raise ValueError(f"channel mismatch: {x.shape} x {w.shape}")
    if h < kh or wd < kw:
        raise ValueError(f"input {x.shape} smaller than filter {w.shape}")
    oh, ow = h - kh + 1, wd - kw + 1

    block_b = min(block_b, b)
    pad_b = (-b) % block_b
    if pad_b:
        x = jnp.pad(x, ((0, pad_b), (0, 0), (0, 0), (0, 0)))
    bp = x.shape[0]

    out = pl.pallas_call(
        functools.partial(_conv2d_kernel, kh=kh, kw=kw),
        grid=(bp // block_b,),
        in_specs=[
            pl.BlockSpec((block_b, h, wd, cin), lambda i: (i, 0, 0, 0)),
            pl.BlockSpec((kh, kw, cin, cout), lambda i: (0, 0, 0, 0)),
        ],
        out_specs=pl.BlockSpec((block_b, oh, ow, cout), lambda i: (i, 0, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((bp, oh, ow, cout), jnp.float32),
        interpret=interpret,
    )(x.astype(jnp.float32), w.astype(jnp.float32))
    return out[:b]


@jax.custom_vjp
def conv2d(x: jnp.ndarray, w: jnp.ndarray) -> jnp.ndarray:
    """Differentiable Pallas VALID conv: NHWC x HWIO -> NHWC."""
    return conv2d_pallas(x, w)


def _conv2d_fwd(x, w):
    return conv2d_pallas(x, w), (x, w)


def _conv2d_bwd(res, g):
    x, w = res
    kh, kw, cin, cout = w.shape
    b, oh, ow, _ = g.shape

    # dx: full-correlation of g with the 180-rotated, io-swapped filter --
    # the same Pallas conv kernel on a padded cotangent.
    g_pad = jnp.pad(g, ((0, 0), (kh - 1, kh - 1), (kw - 1, kw - 1), (0, 0)))
    w_rot = jnp.flip(w, axis=(0, 1)).transpose(0, 1, 3, 2)  # [KH,KW,Cout,Cin]
    dx = conv2d_pallas(g_pad, w_rot)

    # dw: ONE im2col-style Pallas matmul over all (kh, kw) taps at once —
    # (KH*KW*Cin, B*OH*OW) x (B*OH*OW, Cout). Replacing the previous
    # per-tap loop (9 separate kernels) cut the BraggNN train step 6x
    # (EXPERIMENTS.md §Perf).
    g2 = g.reshape(b * oh * ow, cout)
    taps = []
    for i in range(kh):
        for j in range(kw):
            taps.append(x[:, i : i + oh, j : j + ow, :].reshape(b * oh * ow, cin))
    xs_all = jnp.concatenate(taps, axis=1)  # [B*OH*OW, KH*KW*Cin]
    dw = matmul_pallas(xs_all.T, g2).reshape(kh, kw, cin, cout)
    return dx, dw


conv2d.defvjp(_conv2d_fwd, _conv2d_bwd)


def conv2d_bias(
    x: jnp.ndarray, w: jnp.ndarray, b: jnp.ndarray, *, padding: str = "VALID"
) -> jnp.ndarray:
    """Conv + bias with SAME/VALID handling at the jnp level."""
    if padding == "SAME":
        kh, kw = w.shape[0], w.shape[1]
        ph0, ph1 = (kh - 1) // 2, kh // 2
        pw0, pw1 = (kw - 1) // 2, kw // 2
        x = jnp.pad(x, ((0, 0), (ph0, ph1), (pw0, pw1), (0, 0)))
    elif padding != "VALID":
        raise ValueError(f"unknown padding {padding!r}")
    return conv2d(x, w) + b[None, None, None, :]
