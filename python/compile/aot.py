"""AOT lowering: JAX (L2) + Pallas (L1) -> HLO text artifacts for rust (L3).

Emits, into `artifacts/`:

    <model>_train.hlo.txt   one Adam step, flat ABI (see model.py docstring)
    <model>_infer.hlo.txt   batched forward pass
    <model>_meta.json       tensor names/shapes + ABI layout for rust
    pv_surface.hlo.txt      Pallas pseudo-Voigt synthesis (data generator)
    pv_meta.json
    init/<model>_p<i>.npy   He-init parameter snapshots (seed 42) so the
                            rust trainer starts from the same state pytest
                            verified
    manifest.json           artifact index + input digest (staleness check)

Interchange is HLO **text**, not `.serialize()`: jax >= 0.5 emits
HloModuleProto with 64-bit instruction ids which the xla crate's
xla_extension 0.5.1 rejects (`proto.id() <= INT_MAX`); the text parser
reassigns ids and round-trips cleanly (see /opt/xla-example/README.md).

Python runs ONCE at build time (`make artifacts`); nothing here is on the
rust request path.
"""

import argparse
import hashlib
import json
import pathlib
import sys

import jax
import jax.numpy as jnp
import numpy as np
from jax._src.lib import xla_client as xc

from . import model as M
from .kernels import pseudo_voigt

PV_BATCH = 256
PV_PATCH = 11  # Bragg peak patches are 11x11 (paper §4.2)


def to_hlo_text(lowered) -> str:
    """StableHLO -> XlaComputation -> HLO text (return_tuple=True)."""
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def _specs(shapes) -> list:
    return [jax.ShapeDtypeStruct(s, d) for (s, d) in shapes]


def lower_model(spec: M.ModelSpec, outdir: pathlib.Path) -> dict:
    train_shapes = M.train_arg_shapes(spec)
    infer_shapes = M.infer_arg_shapes(spec)

    train_fn = M.make_train_step(spec)
    infer_fn = M.make_infer(spec)

    print(f"[aot] lowering {spec.name} train step "
          f"({len(train_shapes)} args, batch={spec.train_batch})", flush=True)
    train_hlo = to_hlo_text(jax.jit(train_fn).lower(*_specs(train_shapes)))
    train_file = f"{spec.name}_train.hlo.txt"
    (outdir / train_file).write_text(train_hlo)

    print(f"[aot] lowering {spec.name} infer (batch={spec.infer_batch})", flush=True)
    infer_hlo = to_hlo_text(jax.jit(infer_fn).lower(*_specs(infer_shapes)))
    infer_file = f"{spec.name}_infer.hlo.txt"
    (outdir / infer_file).write_text(infer_hlo)

    # Initial parameters: the rust trainer loads these to start from the
    # exact pytest-verified state. Raw little-endian f32, C order.
    init_dir = outdir / "init"
    init_dir.mkdir(exist_ok=True)
    params = M.init_params(spec, jax.random.PRNGKey(42))
    init_files = []
    for i, (ps, p) in enumerate(zip(spec.params, params)):
        fname = f"init/{spec.name}_p{i}.bin"
        np.asarray(p, dtype="<f4").tofile(outdir / fname)
        init_files.append(fname)

    n = spec.n_params
    meta = {
        "name": spec.name,
        "param_count": spec.param_count,
        "params": [
            {"name": ps.name, "shape": list(ps.shape), "init": init_files[i]}
            for i, ps in enumerate(spec.params)
        ],
        "input_shape": list(spec.input_shape),
        "target_shape": list(spec.target_shape),
        "train_batch": spec.train_batch,
        "infer_batch": spec.infer_batch,
        "adam": {
            "lr": M.ADAM_LR,
            "beta1": M.ADAM_B1,
            "beta2": M.ADAM_B2,
            "eps": M.ADAM_EPS,
        },
        "fwd_flops_per_sample": M.fwd_flops_per_sample(spec),
        "train_flops_per_step": M.train_flops_per_step(spec),
        "sample_bytes": 2 * int(np.prod(spec.input_shape))
        + 4 * int(np.prod(spec.target_shape)),  # 16-bit pixels + f32 labels
        "train": {
            "file": train_file,
            # arg order: params*n, m*n, v*n, step, x, y
            "n_args": 3 * n + 3,
            "n_outputs": 3 * n + 2,  # params', m', v', step', loss
            "arg_shapes": [list(s) for (s, _) in M.train_arg_shapes(spec)],
        },
        "infer": {
            "file": infer_file,
            "n_args": n + 1,
            "n_outputs": 1,
            "arg_shapes": [list(s) for (s, _) in M.infer_arg_shapes(spec)],
        },
    }
    (outdir / f"{spec.name}_meta.json").write_text(json.dumps(meta, indent=1))
    return meta


def lower_pv(outdir: pathlib.Path) -> dict:
    """The L1 pseudo-Voigt kernel as a standalone data-synthesis artifact."""
    print(f"[aot] lowering pv_surface (P={PV_BATCH}, {PV_PATCH}x{PV_PATCH})",
          flush=True)

    def pv(params):
        return (pseudo_voigt(params, height=PV_PATCH, width=PV_PATCH),)

    lowered = jax.jit(pv).lower(
        jax.ShapeDtypeStruct((PV_BATCH, 7), jnp.float32)
    )
    (outdir / "pv_surface.hlo.txt").write_text(to_hlo_text(lowered))
    meta = {
        "file": "pv_surface.hlo.txt",
        "batch": PV_BATCH,
        "height": PV_PATCH,
        "width": PV_PATCH,
        "param_order": ["amp", "x0", "y0", "sigma_x", "sigma_y", "eta", "bg"],
    }
    (outdir / "pv_meta.json").write_text(json.dumps(meta, indent=1))
    return meta


def input_digest() -> str:
    """Digest of every python source feeding the artifacts."""
    root = pathlib.Path(__file__).parent
    h = hashlib.sha256()
    for f in sorted(root.rglob("*.py")):
        h.update(f.read_bytes())
    return h.hexdigest()


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--out", default="../artifacts", help="artifact directory")
    ap.add_argument(
        "--models", nargs="*", default=list(M.MODELS), help="subset of models"
    )
    args = ap.parse_args()

    outdir = pathlib.Path(args.out)
    outdir.mkdir(parents=True, exist_ok=True)

    manifest = {"digest": input_digest(), "models": {}, "jax": jax.__version__}
    for name in args.models:
        manifest["models"][name] = lower_model(M.MODELS[name], outdir)["train"][
            "file"
        ]
    manifest["pv"] = lower_pv(outdir)["file"]
    (outdir / "manifest.json").write_text(json.dumps(manifest, indent=1))
    sizes = {
        f.name: f.stat().st_size for f in sorted(outdir.glob("*.hlo.txt"))
    }
    print(f"[aot] wrote {len(sizes)} HLO modules: "
          + ", ".join(f"{k} ({v//1024} KiB)" for k, v in sizes.items()))


if __name__ == "__main__":
    sys.exit(main())
