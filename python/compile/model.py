"""L2: the paper's two DNNs in JAX, built on the L1 Pallas kernels.

* **BraggNN** (Liu et al. 2020, §5.2) — localizes a Bragg peak center
  (x, y) inside an 11x11 detector patch. Three VALID 3x3 conv blocks
  (64, 32, 8 channels) + four dense layers (64, 32, 16, 2). 36,922
  parameters — "lightweight by design" per the paper.

* **CookieNetAE** (§5.2) — estimates the per-channel electron-energy
  probability density for the 16-channel CookieBox eToF array. Eight SAME
  3x3 conv layers over a 16x128 energy-histogram image, ReLU everywhere,
  314,401 parameters (paper: 343,937 — same depth/class, channel widths
  chosen as [32,64,96,96,96,64,32,1]; documented in DESIGN.md).

Both models train with MSE + Adam(1e-3) exactly as §5.2 describes. The
train step is expressed over a *flat* tuple ABI so `aot.py` can lower it
once and the rust runtime can feed literals positionally:

    train:  (p_0..p_{n-1}, m_0..m_{n-1}, v_0..v_{n-1}, step, x, y)
         -> (p'_0..p'_{n-1}, m'_.., v'_.., step+1, loss)
    infer:  (p_0..p_{n-1}, x) -> (y_hat,)

Every conv/dense in fwd AND bwd goes through the Pallas kernels
(custom_vjp), so the AOT HLO the rust side executes is kernel-generated
end to end.
"""

import dataclasses
from typing import Callable, Sequence

import jax
import jax.numpy as jnp

from .kernels import conv2d_bias, dense

# --------------------------------------------------------------------------
# Model specs
# --------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class ParamSpec:
    name: str
    shape: tuple


@dataclasses.dataclass(frozen=True)
class ModelSpec:
    """Static description of a model: what aot.py lowers and rust loads."""

    name: str
    params: tuple  # tuple[ParamSpec, ...]
    input_shape: tuple  # per-sample x shape
    target_shape: tuple  # per-sample y shape
    train_batch: int
    infer_batch: int

    @property
    def n_params(self) -> int:
        return len(self.params)

    @property
    def param_count(self) -> int:
        total = 0
        for p in self.params:
            n = 1
            for d in p.shape:
                n *= d
            total += n
        return total


def _conv_spec(name: str, kh: int, kw: int, cin: int, cout: int):
    return [
        ParamSpec(f"{name}_w", (kh, kw, cin, cout)),
        ParamSpec(f"{name}_b", (cout,)),
    ]


def _fc_spec(name: str, fin: int, fout: int):
    return [ParamSpec(f"{name}_w", (fin, fout)), ParamSpec(f"{name}_b", (fout,))]


BRAGGNN_CONVS = [(1, 64), (64, 32), (32, 8)]  # VALID 3x3: 11 -> 9 -> 7 -> 5
BRAGGNN_FCS = [(5 * 5 * 8, 64), (64, 32), (32, 16), (16, 2)]

_bragg_params = []
for i, (ci, co) in enumerate(BRAGGNN_CONVS):
    _bragg_params += _conv_spec(f"conv{i+1}", 3, 3, ci, co)
for i, (fi, fo) in enumerate(BRAGGNN_FCS):
    _bragg_params += _fc_spec(f"fc{i+1}", fi, fo)

BRAGGNN = ModelSpec(
    name="braggnn",
    params=tuple(_bragg_params),
    input_shape=(11, 11, 1),
    target_shape=(2,),
    train_batch=128,
    infer_batch=512,
)

COOKIE_CHANNELS = [1, 32, 64, 96, 96, 96, 64, 32, 1]  # 8 SAME 3x3 convs

_cookie_params = []
for i, (ci, co) in enumerate(zip(COOKIE_CHANNELS[:-1], COOKIE_CHANNELS[1:])):
    _cookie_params += _conv_spec(f"conv{i+1}", 3, 3, ci, co)

COOKIENETAE = ModelSpec(
    name="cookienetae",
    params=tuple(_cookie_params),
    input_shape=(16, 128, 1),
    target_shape=(16, 128, 1),
    train_batch=4,
    infer_batch=8,
)

MODELS = {m.name: m for m in (BRAGGNN, COOKIENETAE)}


# --------------------------------------------------------------------------
# Init
# --------------------------------------------------------------------------


def init_params(spec: ModelSpec, key: jax.Array) -> list:
    """He-normal weights, zero biases, in spec order."""
    params = []
    keys = jax.random.split(key, len(spec.params))
    for ps, k in zip(spec.params, keys):
        if ps.name.endswith("_b"):
            params.append(jnp.zeros(ps.shape, jnp.float32))
        else:
            fan_in = 1
            for d in ps.shape[:-1]:
                fan_in *= d
            std = (2.0 / fan_in) ** 0.5
            params.append(std * jax.random.normal(k, ps.shape, jnp.float32))
    return params


# --------------------------------------------------------------------------
# Forward passes (all compute through Pallas kernels)
# --------------------------------------------------------------------------


def braggnn_fwd(params: Sequence[jnp.ndarray], x: jnp.ndarray) -> jnp.ndarray:
    """x: [B, 11, 11, 1] -> normalized (row, col) peak center in [0,1]^2."""
    (c1w, c1b, c2w, c2b, c3w, c3b,
     f1w, f1b, f2w, f2b, f3w, f3b, f4w, f4b) = params
    h = jax.nn.relu(conv2d_bias(x, c1w, c1b, padding="VALID"))
    h = jax.nn.relu(conv2d_bias(h, c2w, c2b, padding="VALID"))
    h = jax.nn.relu(conv2d_bias(h, c3w, c3b, padding="VALID"))
    h = h.reshape(h.shape[0], -1)
    h = jax.nn.relu(dense(h, f1w, f1b))
    h = jax.nn.relu(dense(h, f2w, f2b))
    h = jax.nn.relu(dense(h, f3w, f3b))
    return dense(h, f4w, f4b)


def cookienetae_fwd(params: Sequence[jnp.ndarray], x: jnp.ndarray) -> jnp.ndarray:
    """x: [B, 16, 128, 1] energy histograms -> per-channel energy pdf."""
    h = x
    n_layers = len(params) // 2
    for i in range(n_layers):
        w, b = params[2 * i], params[2 * i + 1]
        h = conv2d_bias(h, w, b, padding="SAME")
        h = jax.nn.relu(h)  # paper: rectifier on all layers, output included
    return h


FORWARDS: dict = {
    "braggnn": braggnn_fwd,
    "cookienetae": cookienetae_fwd,
}


def mse_loss(fwd: Callable, params: Sequence[jnp.ndarray], x, y) -> jnp.ndarray:
    pred = fwd(params, x)
    return jnp.mean((pred - y) ** 2)


# --------------------------------------------------------------------------
# Adam train step (flat ABI)
# --------------------------------------------------------------------------

ADAM_LR = 1e-3
ADAM_B1 = 0.9
ADAM_B2 = 0.999
ADAM_EPS = 1e-8


def make_train_step(spec: ModelSpec) -> Callable:
    """Returns train_step(*flat_args) -> flat_outputs (see module doc)."""
    fwd = FORWARDS[spec.name]
    n = spec.n_params

    def train_step(*args):
        params = list(args[:n])
        m = list(args[n : 2 * n])
        v = list(args[2 * n : 3 * n])
        step = args[3 * n]
        x, y = args[3 * n + 1], args[3 * n + 2]

        loss, grads = jax.value_and_grad(
            lambda p: mse_loss(fwd, p, x, y)
        )(params)

        t = step + 1.0
        b1t = ADAM_B1**t
        b2t = ADAM_B2**t
        new_p, new_m, new_v = [], [], []
        for p, g, mi, vi in zip(params, grads, m, v):
            mi = ADAM_B1 * mi + (1.0 - ADAM_B1) * g
            vi = ADAM_B2 * vi + (1.0 - ADAM_B2) * g * g
            m_hat = mi / (1.0 - b1t)
            v_hat = vi / (1.0 - b2t)
            new_p.append(p - ADAM_LR * m_hat / (jnp.sqrt(v_hat) + ADAM_EPS))
            new_m.append(mi)
            new_v.append(vi)
        return (*new_p, *new_m, *new_v, t, loss)

    return train_step


def make_infer(spec: ModelSpec) -> Callable:
    fwd = FORWARDS[spec.name]
    n = spec.n_params

    def infer(*args):
        params = list(args[:n])
        x = args[n]
        return (fwd(params, x),)

    return infer


def fwd_flops_per_sample(spec: ModelSpec) -> int:
    """Analytic multiply-add FLOPs (x2) of one forward sample.

    This is the *algorithmic* cost a real accelerator executes, used by the
    rust `accel` performance models; it deliberately excludes the
    interpret-mode emulation overhead of the CPU artifacts.
    """
    if spec.name == "braggnn":
        flops = 0
        h = 11
        for ci, co in BRAGGNN_CONVS:  # VALID 3x3
            h -= 2
            flops += 2 * h * h * 9 * ci * co
        for fi, fo in BRAGGNN_FCS:
            flops += 2 * fi * fo
        return flops
    if spec.name == "cookienetae":
        flops = 0
        for ci, co in zip(COOKIE_CHANNELS[:-1], COOKIE_CHANNELS[1:]):
            flops += 2 * 16 * 128 * 9 * ci * co  # SAME 3x3
        return flops
    raise ValueError(spec.name)


def train_flops_per_step(spec: ModelSpec) -> int:
    """fwd + bwd (~2x fwd) over the batch, plus ~10 FLOPs/param of Adam."""
    return 3 * spec.train_batch * fwd_flops_per_sample(spec) + 10 * spec.param_count


def train_arg_shapes(spec: ModelSpec) -> list:
    """[(shape, dtype)] in positional order for the train-step ABI."""
    shapes = [ps.shape for ps in spec.params]
    flat = shapes * 3  # params, m, v
    flat.append(())  # step (f32 scalar)
    flat.append((spec.train_batch, *spec.input_shape))  # x
    flat.append((spec.train_batch, *spec.target_shape))  # y
    return [(s, jnp.float32) for s in flat]


def infer_arg_shapes(spec: ModelSpec) -> list:
    shapes = [ps.shape for ps in spec.params]
    shapes.append((spec.infer_batch, *spec.input_shape))
    return [(s, jnp.float32) for s in shapes]
