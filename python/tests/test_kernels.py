"""L1 correctness: every Pallas kernel vs its pure-jnp oracle.

hypothesis sweeps shapes (and the f32/bf16 input dtypes the kernels
accept); assert_allclose against ref.py is the contract the AOT artifacts
inherit.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import (
    conv2d,
    conv2d_bias,
    conv2d_pallas,
    dense,
    matmul,
    matmul_pallas,
    pseudo_voigt,
)
from compile.kernels.ref import (
    conv2d_ref,
    dense_ref,
    matmul_ref,
    pseudo_voigt_ref,
)

HYPO = dict(max_examples=25, deadline=None)


def rand(rng, shape, dtype=np.float32):
    return rng.normal(size=shape).astype(dtype)


# ---------------------------------------------------------------- matmul


@settings(**HYPO)
@given(
    m=st.integers(1, 200),
    k=st.integers(1, 160),
    n=st.integers(1, 130),
    seed=st.integers(0, 2**31 - 1),
)
def test_matmul_matches_ref(m, k, n, seed):
    rng = np.random.default_rng(seed)
    a, b = rand(rng, (m, k)), rand(rng, (k, n))
    got = matmul_pallas(jnp.asarray(a), jnp.asarray(b))
    np.testing.assert_allclose(got, matmul_ref(a, b), rtol=1e-4, atol=1e-4)


@settings(**HYPO)
@given(
    m=st.integers(1, 64),
    k=st.integers(1, 64),
    n=st.integers(1, 64),
    bm=st.sampled_from([8, 32, 128]),
    bn=st.sampled_from([8, 32, 128]),
    bk=st.sampled_from([8, 32, 128]),
)
def test_matmul_block_shape_invariance(m, k, n, bm, bn, bk):
    """The result must not depend on the BlockSpec tiling."""
    rng = np.random.default_rng(7)
    a, b = rand(rng, (m, k)), rand(rng, (k, n))
    got = matmul_pallas(
        jnp.asarray(a), jnp.asarray(b), block_m=bm, block_n=bn, block_k=bk
    )
    np.testing.assert_allclose(got, matmul_ref(a, b), rtol=1e-4, atol=1e-4)


def test_matmul_bf16_inputs_accumulate_f32():
    rng = np.random.default_rng(3)
    a = rng.normal(size=(32, 48)).astype(jnp.bfloat16)
    b = rng.normal(size=(48, 16)).astype(jnp.bfloat16)
    got = matmul_pallas(jnp.asarray(a), jnp.asarray(b))
    assert got.dtype == jnp.float32
    ref = matmul_ref(np.asarray(a, np.float32), np.asarray(b, np.float32))
    np.testing.assert_allclose(got, ref, rtol=2e-2, atol=2e-2)


def test_matmul_grad_matches_jnp():
    rng = np.random.default_rng(5)
    a, b = rand(rng, (16, 20)), rand(rng, (20, 8))
    f = lambda a, b: jnp.sum(matmul(a, b) ** 2)
    fr = lambda a, b: jnp.sum((a @ b) ** 2)
    ga, gb = jax.grad(f, (0, 1))(jnp.asarray(a), jnp.asarray(b))
    gar, gbr = jax.grad(fr, (0, 1))(jnp.asarray(a), jnp.asarray(b))
    np.testing.assert_allclose(ga, gar, rtol=1e-4, atol=1e-4)
    np.testing.assert_allclose(gb, gbr, rtol=1e-4, atol=1e-4)


def test_matmul_rejects_bad_shapes():
    with pytest.raises(ValueError):
        matmul_pallas(jnp.zeros((2, 3)), jnp.zeros((4, 5)))
    with pytest.raises(ValueError):
        matmul_pallas(jnp.zeros((2, 3, 4)), jnp.zeros((4, 5)))


def test_dense_bias():
    rng = np.random.default_rng(11)
    x, w, b = rand(rng, (10, 20)), rand(rng, (20, 5)), rand(rng, (5,))
    got = dense(jnp.asarray(x), jnp.asarray(w), jnp.asarray(b))
    np.testing.assert_allclose(got, dense_ref(x, w, b), rtol=1e-4, atol=1e-4)


# ---------------------------------------------------------------- conv2d


@settings(**HYPO)
@given(
    b=st.integers(1, 12),
    extra_h=st.integers(0, 12),
    extra_w=st.integers(0, 12),
    cin=st.sampled_from([1, 3, 16]),
    cout=st.sampled_from([1, 8, 32]),
    ksz=st.sampled_from([1, 3, 5]),
    seed=st.integers(0, 2**31 - 1),
)
def test_conv2d_matches_ref(b, extra_h, extra_w, cin, cout, ksz, seed):
    rng = np.random.default_rng(seed)
    h, w = ksz + extra_h, ksz + extra_w
    x = rand(rng, (b, h, w, cin))
    wt = rand(rng, (ksz, ksz, cin, cout))
    got = conv2d_pallas(jnp.asarray(x), jnp.asarray(wt))
    np.testing.assert_allclose(got, conv2d_ref(x, wt), rtol=1e-4, atol=1e-4)


@settings(**HYPO)
@given(bb=st.sampled_from([1, 2, 8, 16]), b=st.integers(1, 9))
def test_conv2d_batch_block_invariance(bb, b):
    rng = np.random.default_rng(13)
    x = rand(rng, (b, 11, 11, 2))
    wt = rand(rng, (3, 3, 2, 4))
    got = conv2d_pallas(jnp.asarray(x), jnp.asarray(wt), block_b=bb)
    np.testing.assert_allclose(got, conv2d_ref(x, wt), rtol=1e-4, atol=1e-4)


def test_conv2d_same_padding_matches_lax():
    rng = np.random.default_rng(17)
    x = rand(rng, (2, 16, 128, 3))
    wt = rand(rng, (3, 3, 3, 4))
    bias = rand(rng, (4,))
    got = conv2d_bias(
        jnp.asarray(x), jnp.asarray(wt), jnp.asarray(bias), padding="SAME"
    )
    ref = (
        jax.lax.conv_general_dilated(
            x, wt, (1, 1), "SAME", dimension_numbers=("NHWC", "HWIO", "NHWC")
        )
        + bias
    )
    np.testing.assert_allclose(got, ref, rtol=1e-4, atol=1e-4)


def test_conv2d_grad_matches_ref():
    rng = np.random.default_rng(19)
    x = rand(rng, (2, 7, 7, 3))
    wt = rand(rng, (3, 3, 3, 4))
    f = lambda x, w: jnp.sum(conv2d(x, w) ** 2)
    fr = lambda x, w: jnp.sum(conv2d_ref(x, w) ** 2)
    gx, gw = jax.grad(f, (0, 1))(jnp.asarray(x), jnp.asarray(wt))
    gxr, gwr = jax.grad(fr, (0, 1))(jnp.asarray(x), jnp.asarray(wt))
    np.testing.assert_allclose(gx, gxr, rtol=1e-4, atol=1e-4)
    np.testing.assert_allclose(gw, gwr, rtol=1e-4, atol=1e-4)


def test_conv2d_rejects_bad_shapes():
    with pytest.raises(ValueError):
        conv2d_pallas(jnp.zeros((2, 5, 5, 3)), jnp.zeros((3, 3, 4, 8)))
    with pytest.raises(ValueError):
        conv2d_pallas(jnp.zeros((2, 2, 2, 3)), jnp.zeros((3, 3, 3, 8)))
    with pytest.raises(ValueError):
        conv2d_bias(
            jnp.zeros((1, 5, 5, 1)),
            jnp.zeros((3, 3, 1, 1)),
            jnp.zeros((1,)),
            padding="FULL",
        )


# ---------------------------------------------------------- pseudo-Voigt


@settings(**HYPO)
@given(
    p=st.integers(1, 300),
    h=st.sampled_from([8, 11, 16]),
    w=st.sampled_from([8, 11, 128]),
    seed=st.integers(0, 2**31 - 1),
)
def test_pseudo_voigt_matches_ref(p, h, w, seed):
    rng = np.random.default_rng(seed)
    params = np.stack(
        [
            rng.uniform(10, 500, p),      # amp
            rng.uniform(1, w - 2, p),     # x0
            rng.uniform(1, h - 2, p),     # y0
            rng.uniform(0.3, 4, p),       # sigma_x
            rng.uniform(0.3, 4, p),       # sigma_y
            rng.uniform(0, 1, p),         # eta
            rng.uniform(0, 10, p),        # bg
        ],
        axis=1,
    ).astype(np.float32)
    got = pseudo_voigt(jnp.asarray(params), height=h, width=w)
    ref = pseudo_voigt_ref(params, h, w)
    np.testing.assert_allclose(got, ref, rtol=1e-5, atol=1e-3)


def test_pseudo_voigt_eta_limits():
    """eta=0 must be the pure Gaussian, eta=1 the pure Lorentzian."""
    base = np.array([[100.0, 5.0, 5.0, 1.5, 2.0, 0.0, 1.0]], np.float32)
    g = np.asarray(pseudo_voigt(jnp.asarray(base), height=11, width=11))
    base[0, 5] = 1.0
    l = np.asarray(pseudo_voigt(jnp.asarray(base), height=11, width=11))
    rows = np.arange(11.0)[:, None] - 5.0
    cols = np.arange(11.0)[None, :] - 5.0
    gx = cols**2 / 1.5**2
    gy = rows**2 / 2.0**2
    np.testing.assert_allclose(
        g[0], 100 * np.exp(-0.5 * (gx + gy)) + 1, rtol=1e-5, atol=1e-4
    )
    np.testing.assert_allclose(
        l[0], 100 / (1 + gx + gy) + 1, rtol=1e-5, atol=1e-4
    )


def test_pseudo_voigt_peak_at_center():
    """The maximum must land on the integer pixel nearest (x0, y0)."""
    params = np.array([[200.0, 3.0, 7.0, 1.0, 1.0, 0.3, 0.0]], np.float32)
    out = np.asarray(pseudo_voigt(jnp.asarray(params), height=11, width=11))[0]
    r, c = np.unravel_index(np.argmax(out), out.shape)
    assert (r, c) == (7, 3)


def test_pseudo_voigt_rejects_bad_shapes():
    with pytest.raises(ValueError):
        pseudo_voigt(jnp.zeros((4, 6)), height=8, width=8)
