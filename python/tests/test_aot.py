"""AOT pipeline: HLO text artifacts exist, parse, and carry the right ABI."""

import json
import pathlib

import numpy as np
import pytest

ARTIFACTS = pathlib.Path(__file__).resolve().parents[2] / "artifacts"

pytestmark = pytest.mark.skipif(
    not (ARTIFACTS / "manifest.json").exists(),
    reason="run `make artifacts` first",
)


def _meta(name):
    return json.loads((ARTIFACTS / f"{name}_meta.json").read_text())


@pytest.mark.parametrize("model", ["braggnn", "cookienetae"])
def test_hlo_text_entry_computation(model):
    for phase in ("train", "infer"):
        text = (ARTIFACTS / f"{model}_{phase}.hlo.txt").read_text()
        assert text.startswith("HloModule"), f"{model}_{phase} not HLO text"
        assert "ENTRY" in text
        # jax>=0.5 proto ids overflow the crate's XLA; text is the contract
        assert not text.startswith("\x08"), "binary proto snuck in"


@pytest.mark.parametrize("model", ["braggnn", "cookienetae"])
def test_meta_abi_layout(model):
    meta = _meta(model)
    n = len(meta["params"])
    assert meta["train"]["n_args"] == 3 * n + 3
    assert meta["train"]["n_outputs"] == 3 * n + 2
    assert meta["infer"]["n_args"] == n + 1
    shapes = meta["train"]["arg_shapes"]
    # params, m, v share shapes
    for i in range(n):
        assert shapes[i] == shapes[n + i] == shapes[2 * n + i]
        assert shapes[i] == meta["params"][i]["shape"]
    assert shapes[3 * n] == []  # scalar step
    assert shapes[3 * n + 1] == [meta["train_batch"], *meta["input_shape"]]


@pytest.mark.parametrize("model", ["braggnn", "cookienetae"])
def test_hlo_parameter_arity_matches_meta(model):
    """The ENTRY parameter count in the HLO text must equal the meta ABI."""
    meta = _meta(model)
    for phase in ("train", "infer"):
        text = (ARTIFACTS / f"{model}_{phase}.hlo.txt").read_text()
        entry = text[text.index("ENTRY") :]
        # entry params appear as `... = f32[...] parameter(K)` lines
        n_params = entry.count(" parameter(")
        assert n_params == meta[phase]["n_args"], (model, phase, n_params)


@pytest.mark.parametrize("model", ["braggnn", "cookienetae"])
def test_init_snapshots(model):
    meta = _meta(model)
    total = 0
    for p in meta["params"]:
        raw = np.fromfile(ARTIFACTS / p["init"], dtype="<f4")
        want = int(np.prod(p["shape"])) if p["shape"] else 1
        assert raw.size == want, p["name"]
        assert np.all(np.isfinite(raw)), p["name"]
        total += raw.size
    assert total == meta["param_count"]


def test_pv_meta():
    meta = json.loads((ARTIFACTS / "pv_meta.json").read_text())
    assert meta["param_order"] == [
        "amp", "x0", "y0", "sigma_x", "sigma_y", "eta", "bg",
    ]
    text = (ARTIFACTS / meta["file"]).read_text()
    assert text.startswith("HloModule")


def test_manifest_digest_current():
    """Artifacts must be regenerated when compile/ sources change."""
    from compile.aot import input_digest

    manifest = json.loads((ARTIFACTS / "manifest.json").read_text())
    assert manifest["digest"] == input_digest(), (
        "artifacts stale: run `make artifacts`"
    )
