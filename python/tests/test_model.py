"""L2 correctness: model shapes, Adam semantics, convergence."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile import model as M


@pytest.fixture(scope="module")
def key():
    return jax.random.PRNGKey(0)


# ------------------------------------------------------------------ specs


def test_braggnn_param_count():
    # conv(576+64, 18432+32, 2304+8) + fc(12800+64, 2048+32, 512+16, 32+2)
    assert M.BRAGGNN.param_count == 36922
    assert M.BRAGGNN.n_params == 14


def test_cookienetae_param_count():
    # 8 SAME 3x3 convs over channels [1,32,64,96,96,96,64,32,1]
    assert M.COOKIENETAE.param_count == 314401
    assert M.COOKIENETAE.n_params == 16
    # within 10% of the paper's 343,937 (channel widths are not published)
    assert abs(M.COOKIENETAE.param_count - 343937) / 343937 < 0.10


def test_init_matches_spec_shapes(key):
    for spec in M.MODELS.values():
        params = M.init_params(spec, key)
        for ps, p in zip(spec.params, params):
            assert p.shape == ps.shape, ps.name
            assert p.dtype == jnp.float32
        biases = [p for ps, p in zip(spec.params, params) if ps.name.endswith("_b")]
        for b in biases:
            assert float(jnp.abs(b).max()) == 0.0


# ---------------------------------------------------------------- forward


def test_braggnn_forward_shape(key):
    params = M.init_params(M.BRAGGNN, key)
    x = jax.random.normal(key, (5, 11, 11, 1))
    out = M.braggnn_fwd(params, x)
    assert out.shape == (5, 2)
    assert bool(jnp.all(jnp.isfinite(out)))


def test_cookienetae_forward_shape(key):
    params = M.init_params(M.COOKIENETAE, key)
    x = jax.random.normal(key, (2, 16, 128, 1))
    out = M.cookienetae_fwd(params, x)
    assert out.shape == (2, 16, 128, 1)
    # ReLU output layer: non-negative everywhere (it is a pdf estimate)
    assert float(out.min()) >= 0.0


# ------------------------------------------------------------------- adam


def _reference_adam(params, grads, m, v, step):
    """Straight transcription of Kingma & Ba with bias correction."""
    t = step + 1.0
    out_p, out_m, out_v = [], [], []
    for p, g, mi, vi in zip(params, grads, m, v):
        mi = M.ADAM_B1 * mi + (1 - M.ADAM_B1) * g
        vi = M.ADAM_B2 * vi + (1 - M.ADAM_B2) * g * g
        mh = mi / (1 - M.ADAM_B1**t)
        vh = vi / (1 - M.ADAM_B2**t)
        out_p.append(p - M.ADAM_LR * mh / (jnp.sqrt(vh) + M.ADAM_EPS))
        out_m.append(mi)
        out_v.append(vi)
    return out_p, out_m, out_v


def test_train_step_is_adam(key):
    spec = M.BRAGGNN
    n = spec.n_params
    params = M.init_params(spec, key)
    m = [jnp.zeros_like(p) for p in params]
    v = [jnp.zeros_like(p) for p in params]
    x = jax.random.normal(key, (spec.train_batch, *spec.input_shape))
    y = jax.random.uniform(key, (spec.train_batch, *spec.target_shape))

    out = M.make_train_step(spec)(*params, *m, *v, jnp.float32(0.0), x, y)
    got_p, got_m, got_v = out[:n], out[n : 2 * n], out[2 * n : 3 * n]
    assert float(out[3 * n]) == 1.0  # step incremented

    loss, grads = jax.value_and_grad(
        lambda p: M.mse_loss(M.braggnn_fwd, p, x, y)
    )(list(params))
    np.testing.assert_allclose(float(out[-1]), float(loss), rtol=1e-5)
    ref_p, ref_m, ref_v = _reference_adam(params, grads, m, v, 0.0)
    for a, b in zip(got_p, ref_p):
        np.testing.assert_allclose(a, b, rtol=1e-4, atol=1e-6)
    for a, b in zip(got_m, ref_m):
        np.testing.assert_allclose(a, b, rtol=1e-4, atol=1e-7)
    for a, b in zip(got_v, ref_v):
        np.testing.assert_allclose(a, b, rtol=1e-4, atol=1e-9)


def test_braggnn_loss_decreases(key):
    spec = M.BRAGGNN
    n = spec.n_params
    params = M.init_params(spec, key)
    state = [*params,
             *[jnp.zeros_like(p) for p in params],
             *[jnp.zeros_like(p) for p in params],
             jnp.float32(0.0)]
    x = jax.random.normal(key, (spec.train_batch, *spec.input_shape))
    y = jax.random.uniform(key, (spec.train_batch, *spec.target_shape))
    step = jax.jit(M.make_train_step(spec))
    losses = []
    for _ in range(8):
        out = step(*state, x, y)
        losses.append(float(out[-1]))
        state = list(out[: 3 * n + 1])
    assert losses[-1] < losses[0] * 0.7, losses


def test_infer_matches_forward(key):
    for spec in M.MODELS.values():
        params = M.init_params(spec, key)
        x = jax.random.normal(key, (spec.infer_batch, *spec.input_shape))
        (got,) = M.make_infer(spec)(*params, x)
        want = M.FORWARDS[spec.name](params, x)
        np.testing.assert_allclose(got, want, rtol=1e-6)


def test_train_arg_shapes_layout():
    for spec in M.MODELS.values():
        shapes = M.train_arg_shapes(spec)
        n = spec.n_params
        assert len(shapes) == 3 * n + 3
        assert shapes[3 * n][0] == ()  # step scalar
        assert shapes[3 * n + 1][0] == (spec.train_batch, *spec.input_shape)
        assert shapes[3 * n + 2][0] == (spec.train_batch, *spec.target_shape)
